#!/usr/bin/env bash
# Repo health check: bytecode-compiles the tree, runs the fast tier-1 tests,
# smokes the public API registries, and runs the jaxpr-level wire-model &
# strategy-contract audit (repro.analysis). ROADMAP.md references this as the
# pre-PR gate and .github/workflows/ci.yml runs it on every push/PR; run the
# full (slow-inclusive) suite with
#   PYTHONPATH=src python -m pytest -q
#
# CI hardening: every section runs under a hard `timeout` (a hung section
# fails the job instead of eating the runner), the header pins the exact
# python/jax/numpy versions + test seed the run used, and the quickstart
# smoke fails on any DeprecationWarning raised from repro.* code (the
# public example must never exercise a deprecated surface).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# one knob scales every section bound (slow CI runners: SECTION_TIMEOUT_SCALE=3)
T="${SECTION_TIMEOUT_SCALE:-1}"
t() { timeout "$(( $1 * T ))" "${@:2}"; }

echo "== environment header (versions + seed) =="
export PYTEST_SEED="${PYTEST_SEED:-0}"
export PYTHONHASHSEED="${PYTHONHASHSEED:-$PYTEST_SEED}"
t 60 python -c "
import os, platform, sys
import jax, jaxlib, numpy, pytest
print(f'python    {platform.python_version()} ({sys.platform})')
print(f'jax       {jax.__version__}  jaxlib {jaxlib.__version__}')
print(f'numpy     {numpy.__version__}')
print(f'pytest    {pytest.__version__}')
print(f'devices   {jax.device_count()}x {jax.devices()[0].platform}')
print(f'seed      PYTEST_SEED={os.environ[\"PYTEST_SEED\"]} '
      f'PYTHONHASHSEED={os.environ[\"PYTHONHASHSEED\"]}')
"

echo "== compileall =="
t 120 python -m compileall -q src benchmarks examples tests scripts

echo "== lint (ruff, rule set in pyproject.toml) =="
if command -v ruff >/dev/null 2>&1; then
    t 120 ruff check .
else
    echo "ruff not installed; skipped locally (CI installs and enforces it)"
fi

echo "== strategy/source-registry / engine smoke =="
t 300 python -c "
from repro.api import DPMREngine, list_strategies, get_strategy
names = list_strategies()
assert {'a2a', 'allgather', 'psum_scatter', 'hier_a2a',
        'compressed_reduce', 'topk_reduce', 'overlap_a2a',
        'hier_a2a+topk', 'hier_a2a+int8'} <= set(names), \
    names
for n in names:
    get_strategy(n)
from repro.data import list_sources, get_source
snames = list_sources()
assert {'zipf_sparse', 'lm_markov', 'file_sparse'} <= set(snames), snames
from repro.optim import optimizers, schedules
assert {'sgd', 'adagrad', 'momentum'} <= set(optimizers.SPARSE_OPTIMIZERS)
assert {'constant', 'warmup_cosine'} <= set(schedules.SCHEDULES)
print('registries OK:', names, snames)
"

echo "== strategy wire-model smoke (every registered strategy, both tiers) =="
# iterates list_strategies() DYNAMICALLY — a newly registered strategy is
# covered the moment it exists and cannot silently skip the WireBytes check
t 300 python -c "
from repro.api import list_strategies, get_strategy
from repro.api.strategies import StrategyContext, WireBytes
from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(1, 1)
cfg = DPMRConfig(num_features=1 << 12, max_features_per_sample=16)
ctx = dpmr.make_strategy_context(cfg, mesh,
                                 cap=dpmr.capacity(cfg, 128, mesh))
# analytic multi-pod geometry: the two-tier split must be live, not a
# degenerate single-number model
pod = StrategyContext(axes=(), num_shards=8, block_size=1 << 9,
                      capacity=64, outer_shards=2)
for n in list_strategies():
    wb = get_strategy(n).bytes_per_device(ctx)
    assert isinstance(wb, WireBytes), (n, type(wb))
    assert wb.inner >= 0 and wb.outer >= 0, (n, wb)
    assert wb.total == wb.inner + wb.outer, (n, wb)
    assert wb.outer == 0, ('single-pod mesh must not cross DCN', n, wb)
    wp = get_strategy(n).bytes_per_device(pod)
    assert isinstance(wp, WireBytes) and wp.outer > 0, (
        'multi-pod geometry must report DCN bytes', n, wp)
print('wire models OK (inner/outer tiers):', list_strategies())
"

echo "== shard-ownership smoke (chunk-aligned per-host ranges) =="
t 300 python -c "
import tempfile
from repro.data import ShardedLoader, get_source, write_file_corpus
tmp = tempfile.mkdtemp()
write_file_corpus(tmp, get_source('zipf_sparse', batch_size=16,
                                  num_batches=8, num_features=1 << 10,
                                  features_per_sample=8),
                  batches_per_chunk=2)              # 4 chunks
for h in range(2):
    src = get_source('file_sparse', directory=tmp)
    loader = ShardedLoader(src, placement='host', prefetch=0,
                           host_index=h, num_hosts=2)
    assert loader.assignment.kind == 'chunk', loader.assignment
    assert sum(1 for _ in loader.epoch()) == 4
    assert src.read_stats['unique_chunks'] == 2, (h, src.read_stats)
print('shard ownership OK: each host opened only its 2 of 4 chunks')
"

echo "== analysis: wire-model & strategy-contract audit (jaxpr-level) =="
# the hard gate over the registry's WireBytes claims: traces every
# strategy's collectives on single- and multi-pod analytic meshes, cross-
# checks declared vs extracted bytes per tier, and audits the engine seam
# (donation aliasing, carry reset, StepFns cache). The report is written
# to AUDIT_report.json; CI uploads it as an artifact when this fails.
t 600 python -m repro.analysis.audit --quiet --json AUDIT_report.json

# negative control: a deliberately-miswired strategy (legacy self-chunk
# counting) must FAIL the audit — proves the gate can actually reject
t 300 python -c "
from repro.analysis import audit_registry, build_contexts
from repro.api.strategies import _REGISTRY, AllToAllStrategy, WireBytes, \
    register_strategy

class SelfCounting(AllToAllStrategy):
    def bytes_per_device(self, ctx):
        pi = ctx.inner_shards
        return WireBytes(inner=3 * pi * ctx.capacity * 4,
                         outer=3 * (ctx.num_shards - pi) * ctx.capacity * 4)

register_strategy('_miswired_smoke', SelfCounting())
try:
    report = audit_registry(strategies=['_miswired_smoke'],
                            contexts=build_contexts(production=False),
                            engine_checks=False)
finally:
    _REGISTRY.pop('_miswired_smoke', None)
assert not report['ok'], 'auditor accepted a deliberately-miswired strategy'
assert any(f['rule'] == 'W-MATCH' for f in report['findings']), \
    report['findings']
print('negative control OK: miswired strategy rejected '
      f'({report[\"num_findings\"]} findings)')
"

# composition smoke: the registered per-tier composition must trace, price
# BOTH wire tiers, pass a positive audit on the analytic geometries, and
# the autotuner must rank it below flat a2a on the multi-pod geometry
t 300 python -c "
from repro.analysis import audit_registry, build_contexts
from repro.api import autotune, get_strategy
from repro.api.strategies import StrategyContext, WireBytes

pod = StrategyContext(axes=(), num_shards=8, block_size=1 << 9,
                      capacity=64, outer_shards=2)
for name in ('hier_a2a+topk', 'hier_a2a+int8'):
    wb = get_strategy(name).bytes_per_device(pod)
    assert isinstance(wb, WireBytes) and wb.inner > 0 and wb.outer > 0, \
        (name, wb)
report = audit_registry(strategies=['hier_a2a+topk', 'hier_a2a+int8'],
                        contexts=build_contexts(production=False),
                        engine_checks=False)
assert report['ok'], report['findings']
# paper regime (request volume >> table block): the tuner must rank the
# composed DCN-sparsified exchange below flat a2a
regime = pod._replace(capacity=4096)
costs = {s.name: s.cost_s for s in autotune.score_strategies(regime)}
assert costs['hier_a2a+topk'] < costs['a2a'], costs
winner = autotune.choose_strategy(regime)
print('composition smoke OK: compositions priced on both tiers, audited, '
      f'tuner winner at the paper regime = {winner}')
"

echo "== kernels: interpret-mode smoke on CPU (the kernel_impl seam) =="
# the Pallas routing kernels run their python-interpret bodies against the
# kernels/ref.py oracles: select_pack must be BIT-exact (selection + order),
# owner_accumulate bit-exact on integer-valued grads; also proves the
# docs/KERNELS.md worked example executes (tests/test_docs.py re-runs it)
t 300 python -c "
import numpy as np
import jax.numpy as jnp
from repro.kernels import ops, ref

assert ops.normalize_impl('jnp') == 'xla'        # legacy alias maps over
rng = np.random.default_rng(0)
p, cap, k = 4, 64, 16
ids = jnp.asarray(rng.integers(-1, 256, size=(p, cap)).astype(np.int32))
send = jnp.where(ids >= 0,
                 jnp.asarray(rng.normal(size=(p, cap)).astype(np.float32)),
                 0.0)
carry = jnp.where(ids >= 0,
                  jnp.asarray(rng.normal(size=(p, cap)).astype(np.float32)),
                  0.0)
got = ops.select_pack(send, ids, carry, k=k, impl='pallas_interpret')
want = ref.select_pack_ref(send, ids, carry, k=k)
for g, w in zip(got, want):
    np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
g_int = jnp.where(ids >= 0,
                  jnp.asarray(rng.integers(-8, 9,
                                           size=(p, cap)).astype(np.float32)),
                  0.0)
acc = jnp.zeros((256,), jnp.float32)
r0 = ops.owner_accumulate(ids, g_int, acc, 0, impl='xla')
r1 = ops.owner_accumulate(ids, g_int, acc, 0, impl='pallas_interpret')
np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
print('kernels OK: select_pack + owner_accumulate interpret-mode bit-parity')
"

# the kernel guide's worked example, executed exactly as documented
t 300 python -c "
import pathlib, re
text = pathlib.Path('docs/KERNELS.md').read_text()
ns = {}
for i, block in enumerate(re.findall(r'\`\`\`python\n(.*?)\`\`\`', text, re.S)):
    exec(compile(block, f'docs/KERNELS.md#block{i}', 'exec'), ns)
assert ns['kernel_demo_ok'] is True
print('kernels OK: docs/KERNELS.md worked example runs in interpret mode')
"

echo "== docs link-check (every docs/*.md code path exists) =="
t 120 python scripts/check_docs.py

echo "== bench-artifact envelope check (BENCH_*.json) =="
t 120 python scripts/check_bench.py

echo "== quickstart smoke (engine + data plane; deprecation-clean) =="
t 600 python -c "
import runpy, sys, warnings
with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter('always', DeprecationWarning)
    runpy.run_path('examples/quickstart.py', run_name='__main__')
bad = [w for w in caught
       if issubclass(w.category, DeprecationWarning)
       and '/repro/' in (w.filename or '').replace('\\\\', '/')]
for w in bad:
    print(f'DEPRECATION from repro.*: {w.filename}:{w.lineno}: '
          f'{w.message}', file=sys.stderr)
if bad:
    sys.exit('quickstart must not exercise deprecated repro surfaces')
print('quickstart OK (no repro.* DeprecationWarnings)')
"

echo "== serving smoke (coalesced micro-batches bit-exact vs predict) =="
t 300 python -c "
import threading
import numpy as np
from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.serve import BatchingConfig, DPMRServeEngine, HotCacheConfig

mesh = make_host_mesh(1, 1)
cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8)
src = get_source('zipf_sparse', batch_size=4, num_batches=8,
                 num_features=1 << 10, features_per_sample=8, seed=0)
eng = DPMREngine(cfg, mesh)
eng.fit_sgd(src.iter_batches(), steps=4)
srv = DPMRServeEngine(
    eng, batching=BatchingConfig(max_batch=16, max_wait_ms=2.0),
    hot_cache=HotCacheConfig(max_hot=64, threshold=0.0, window=64,
                             refresh_every=1000))
reqs = [src.batch(i) for i in range(8)]
futs = [None] * 8
def client(lo, hi):
    for i in range(lo, hi):
        futs[i] = srv.submit(reqs[i]['ids'], reqs[i]['vals'])
threads = [threading.Thread(target=client, args=(c * 4, c * 4 + 4))
           for c in range(2)]
[t.start() for t in threads]; [t.join() for t in threads]
got = [np.asarray(f.result(timeout=120)) for f in futs]
srv.stop()
for req, g in zip(reqs, got):
    assert np.array_equal(g, eng.predict(req)), 'serving must be bit-exact'
m = srv.metrics_snapshot()
assert m['requests'] == 8 and m['flushes'] >= 1, m
print(f'serving OK: 8 requests, {m[\"flushes\"]} flushes, '
      f'{m.get(\"cache_hits\", 0)} cache hits, bit-exact vs predict')
"

echo "== async-checkpoint roundtrip smoke (snapshot, atomic manifest) =="
# the crash-consistency contract end to end on a tiny engine: an async
# save returns before serialization finishes yet restores bit-exactly
# even though training immediately donates the saved buffers; a torn
# manifest makes that step invisible (restore falls back to the previous
# complete one); tests/test_checkpoint.py holds the full matrix
t 300 python -c "
import os, tempfile, warnings
import numpy as np
from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.runtime.multiprocess import host_value

warnings.simplefilter('ignore', RuntimeWarning)  # detached-cursor notice
tmp = tempfile.mkdtemp()
cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8)
src = get_source('zipf_sparse', batch_size=16, num_batches=8,
                 num_features=1 << 10, features_per_sample=8, seed=0)
eng = DPMREngine(cfg, make_host_mesh(1, 1))
eng.fit_sgd(src.iter_batches(), steps=2)
snap = np.asarray(host_value(eng.state.cold)).copy()
eng.save(tmp, block=False)            # async: snapshot now, write later
eng.fit_sgd(src.iter_batches(), steps=2)   # donates the live buffers
eng.save(tmp, block=False)
eng.wait_saves()
fresh = DPMREngine(cfg, make_host_mesh(1, 1))
man = fresh.restore(tmp)
assert man['step'] == 4, man['step']
mpath = os.path.join(tmp, 'step_0000000004', 'manifest.json')
raw = open(mpath, 'rb').read()
open(mpath, 'wb').write(raw[: len(raw) // 2])   # torn manifest
fresh2 = DPMREngine(cfg, make_host_mesh(1, 1))
man2 = fresh2.restore(tmp)
assert man2['step'] == 2, man2['step']
np.testing.assert_array_equal(np.asarray(host_value(fresh2.state.cold)),
                              snap)
print('async checkpoint OK: snapshot isolation + torn-manifest fallback')
"

echo "== tier-1 tests (fast; -m 'not slow') =="
# must stay under CI's 15-minute job cap so a hang fails HERE with a
# section-level diagnostic, not as a generic job timeout (~7 min healthy).
# When pytest-cov is installed (the `dev` extra; CI always has it) the same
# run also collects line coverage for the api/analysis packages — folded
# into this one invocation so the suite never runs twice
if python -c "import pytest_cov" >/dev/null 2>&1; then
    t 720 python -m pytest -x -q -m "not slow" \
        --cov=repro.api --cov=repro.analysis \
        --cov-report=json:COVERAGE_report.json
else
    t 660 python -m pytest -x -q -m "not slow"
fi

echo "== line coverage: src/repro/api + src/repro/analysis (informational) =="
# REPORTING ONLY — never gates. CI uploads COVERAGE_report.json as an
# artifact on failure so a red run documents what the suite exercised.
if [ -f COVERAGE_report.json ]; then
    t 60 python -c "
import json
rep = json.load(open('COVERAGE_report.json'))
def pct(fragment):
    cov = tot = 0
    for path, entry in rep['files'].items():
        if fragment in path.replace('\\\\', '/'):
            s = entry['summary']
            cov += s['covered_lines']; tot += s['num_statements']
    return cov, tot, 100.0 * cov / max(tot, 1)
for frag, label in (('repro/api/', 'src/repro/api'),
                    ('repro/analysis/', 'src/repro/analysis')):
    cov, tot, p = pct(frag)
    print(f'{label:<22s} {p:5.1f}% lines ({cov}/{tot})')
"
else
    echo "pytest-cov not installed; coverage reporting skipped" \
         "(pip install -e '.[test,dev]' to enable)"
fi

echo "ALL CHECKS PASSED"
