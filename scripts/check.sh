#!/usr/bin/env bash
# Repo health check: bytecode-compiles the tree, runs the fast tier-1 tests,
# and smokes the public API registries. ROADMAP.md references this as the
# pre-PR gate; run the full (slow-inclusive) suite with
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall =="
python -m compileall -q src benchmarks examples tests

echo "== strategy/source-registry / engine smoke =="
python -c "
from repro.api import DPMREngine, list_strategies, get_strategy
names = list_strategies()
assert {'a2a', 'allgather', 'psum_scatter', 'hier_a2a',
        'compressed_reduce'} <= set(names), names
for n in names:
    get_strategy(n)
from repro.data import list_sources, get_source
snames = list_sources()
assert {'zipf_sparse', 'lm_markov', 'file_sparse'} <= set(snames), snames
from repro.optim import optimizers, schedules
assert {'sgd', 'adagrad', 'momentum'} <= set(optimizers.SPARSE_OPTIMIZERS)
assert {'constant', 'warmup_cosine'} <= set(schedules.SCHEDULES)
print('registries OK:', names, snames)
"

echo "== strategy wire-model smoke (every strategy, 1-device mesh, both tiers) =="
python -c "
from repro.api import list_strategies, get_strategy
from repro.api.strategies import WireBytes
from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(1, 1)
cfg = DPMRConfig(num_features=1 << 12, max_features_per_sample=16)
ctx = dpmr.make_strategy_context(cfg, mesh,
                                 cap=dpmr.capacity(cfg, 128, mesh))
for n in list_strategies():
    wb = get_strategy(n).bytes_per_device(ctx)
    assert isinstance(wb, WireBytes), (n, type(wb))
    assert wb.inner >= 0 and wb.outer >= 0, (n, wb)
    assert wb.total == wb.inner + wb.outer, (n, wb)
    assert wb.outer == 0, ('single-pod mesh must not cross DCN', n, wb)
print('wire models OK (inner/outer tiers):', list_strategies())
"

echo "== docs link-check (every docs/*.md code path exists) =="
python scripts/check_docs.py

echo "== quickstart smoke (engine + data plane end to end) =="
python examples/quickstart.py

echo "== tier-1 tests (fast; -m 'not slow') =="
python -m pytest -x -q -m "not slow"

echo "ALL CHECKS PASSED"
