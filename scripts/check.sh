#!/usr/bin/env bash
# Repo health check: bytecode-compiles the tree, runs the fast tier-1 tests,
# and smokes the public API registries. ROADMAP.md references this as the
# pre-PR gate; run the full (slow-inclusive) suite with
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall =="
python -m compileall -q src benchmarks examples tests

echo "== strategy/source-registry / engine smoke =="
python -c "
from repro.api import DPMREngine, list_strategies, get_strategy
names = list_strategies()
assert {'a2a', 'allgather', 'psum_scatter'} <= set(names), names
for n in names:
    get_strategy(n)
from repro.data import list_sources, get_source
snames = list_sources()
assert {'zipf_sparse', 'lm_markov', 'file_sparse'} <= set(snames), snames
from repro.optim import optimizers, schedules
assert {'sgd', 'adagrad', 'momentum'} <= set(optimizers.SPARSE_OPTIMIZERS)
assert {'constant', 'warmup_cosine'} <= set(schedules.SCHEDULES)
print('registries OK:', names, snames)
"

echo "== quickstart smoke (engine + data plane end to end) =="
python examples/quickstart.py

echo "== tier-1 tests (fast; -m 'not slow') =="
python -m pytest -x -q -m "not slow"

echo "ALL CHECKS PASSED"
