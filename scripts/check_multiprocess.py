#!/usr/bin/env python
"""Real-multi-process parity gate: a 2-process `jax.distributed` CPU run
of launch/train.py must bit-match the single-process all-hosts emulation.

Drives three things and diffs their JSON summaries:

  1. baseline: one process, 4 emulated devices,
     `--hosts 2 --host-id -1` (the concatenated global-batch emulation);
  2. the real thing: two coordinated processes (2 local devices each,
     same 4-device global mesh), `--coordinator/--num-processes/
     --process-id`, each serving its own host's stride of the corpus;
  3. the parity assertions:
       - `cold_md5` (the gathered final parameter table) identical — the
         bit-identity claim;
       - `final_eval_loss` (host-side float64 eval on a fixed batch)
         identical — bit-identical loss, computed deterministically;
       - per-step training losses equal to ~1 ulp (the `pmean` metric may
         legitimately differ in reduction order across process
         boundaries — that is why the two exact checks above exist);
       - both processes of the real run report the same digest.

Run locally (takes ~2 min on CPU):  python scripts/check_multiprocess.py
Nightly CI runs it after the slow suite (.github/workflows/ci.yml);
tests/test_multiprocess.py wraps it so `pytest -m slow` covers it too.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PORT = int(os.environ.get("REPRO_MP_PORT", "12741"))

COMMON = ["--sparse", "--strategy", "a2a", "--features", "1024",
          "--batch", "32", "--sparse-batches", "64", "--steps", "6",
          "--mesh-data", "4", "--prefetch", "0", "--save-every", "100",
          "--json", "--log-every", "0"]


def _run(extra: list[str], timeout: int = 600) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)       # --local-devices owns the device count
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", *COMMON, *extra],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)


def _summary(proc: subprocess.Popen, timeout: int = 600) -> dict:
    out, err = proc.communicate(timeout=timeout)
    if proc.returncode != 0:
        sys.exit(f"train.py exited {proc.returncode}:\n{err[-4000:]}")
    return json.loads(out.strip().splitlines()[-1])


def main() -> int:
    print("== baseline: single-process all-hosts emulation "
          "(--hosts 2 --host-id -1, 4 devices) ==")
    base = _summary(_run(["--hosts", "2", "--host-id", "-1",
                          "--local-devices", "4"]))

    print(f"== real run: 2 coordinated processes, 2 local devices each "
          f"(coordinator 127.0.0.1:{PORT}) ==")
    mp = ["--coordinator", f"127.0.0.1:{PORT}",
          "--num-processes", "2", "--local-devices", "2"]
    p1 = _run([*mp, "--process-id", "1"])
    p0 = _run([*mp, "--process-id", "0"])
    s0, s1 = _summary(p0), _summary(p1)

    failures = []
    if s0["cold_md5"] != s1["cold_md5"]:
        failures.append(f"the two processes disagree on the final "
                        f"parameters: {s0['cold_md5']} vs {s1['cold_md5']}")
    if base["cold_md5"] != s0["cold_md5"]:
        failures.append(
            f"final parameters diverge from the emulated baseline: "
            f"emulated {base['cold_md5']} vs real {s0['cold_md5']}")
    if base["final_eval_loss"] != s0["final_eval_loss"]:
        failures.append(
            f"deterministic final eval loss diverges: emulated "
            f"{base['final_eval_loss']!r} vs real {s0['final_eval_loss']!r}")
    for i, (a, b) in enumerate(zip(base["losses"], s0["losses"],
                                   strict=True)):
        if abs(a - b) > 1e-6:
            failures.append(f"step {i} loss diverges beyond metric "
                            f"tolerance: {a!r} vs {b!r}")

    print(f"emulated : eval_loss={base['final_eval_loss']!r} "
          f"cold_md5={base['cold_md5']}")
    print(f"2-process: eval_loss={s0['final_eval_loss']!r} "
          f"cold_md5={s0['cold_md5']}")
    for f in failures:
        print(f"PARITY FAILURE: {f}", file=sys.stderr)
    if not failures:
        print("multiprocess parity OK: bit-identical final parameters + "
              "deterministic eval loss, per-step metric within 1e-6")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
