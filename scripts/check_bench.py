#!/usr/bin/env python
"""Benchmark-artifact check + regression gate.

Envelope mode (default): every `BENCH_*.json` in the repo root must parse
and carry the shared envelope

    {"name": <non-empty str>, "config": <dict>, "results": <non-empty dict>}

so downstream tooling (CI trend lines, cross-PR diffs) can consume any
artifact without per-benchmark knowledge. Writers: see
`benchmarks/input_pipeline.py`, `benchmarks/strategy_hierarchy.py`,
`benchmarks/shard_ownership.py`, `benchmarks/strategy_overlap.py`.

An artifact MAY additionally declare its headline number:

    "primary_metric": {"path": "results.topk_wire_reduction_x",
                       "higher_is_better": true}

`path` is a dotted path into the artifact (integer components index into
lists). When present it is validated — the path must resolve to a number.

Compare mode (the CI bench-regression gate):

    check_bench.py --compare FRESH [BASELINE] [--threshold 0.2]

diffs a freshly produced artifact against the committed baseline (default:
the same filename in the repo root) on the primary metric and exits
non-zero when the fresh value regressed by more than `threshold`
(default 20%) in the metric's bad direction. Both files must pass the
envelope check and at least one must declare `primary_metric` (the fresh
one wins when both do). The nightly CI job runs this for
`BENCH_shard_ownership.json` and `BENCH_strategy_overlap.json`.

Run directly (exits non-zero listing violations) or through
scripts/check.sh / `.github/workflows/ci.yml`.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

ENVELOPE = {"name": str, "config": dict, "results": dict}


def resolve_path(data: dict, dotted: str):
    """Walk `dotted` ("results.sweep.0.x") through dicts and lists;
    returns the value or raises KeyError with the failing component."""
    node = data
    for comp in dotted.split("."):
        if isinstance(node, list):
            try:
                node = node[int(comp)]
            except (ValueError, IndexError):
                raise KeyError(comp) from None
        elif isinstance(node, dict) and comp in node:
            node = node[comp]
        else:
            raise KeyError(comp)
    return node


def check_file(path: pathlib.Path) -> list:
    errors = []
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unparseable JSON ({e})"]
    if not isinstance(data, dict):
        return [f"{path.name}: top level must be an object, "
                f"got {type(data).__name__}"]
    for key, typ in ENVELOPE.items():
        if key not in data:
            errors.append(f"{path.name}: missing envelope key {key!r}")
        elif not isinstance(data[key], typ):
            errors.append(f"{path.name}: {key!r} must be "
                          f"{typ.__name__}, got "
                          f"{type(data[key]).__name__}")
    if isinstance(data.get("name"), str) and not data["name"]:
        errors.append(f"{path.name}: 'name' must be non-empty")
    if isinstance(data.get("results"), dict) and not data["results"]:
        errors.append(f"{path.name}: 'results' must be non-empty")
    pm = data.get("primary_metric")
    if pm is not None:
        if not (isinstance(pm, dict) and isinstance(pm.get("path"), str)
                and isinstance(pm.get("higher_is_better"), bool)):
            errors.append(
                f"{path.name}: 'primary_metric' must be "
                "{path: str, higher_is_better: bool}")
        else:
            try:
                val = resolve_path(data, pm["path"])
            except KeyError as e:
                errors.append(f"{path.name}: primary_metric path "
                              f"{pm['path']!r} does not resolve "
                              f"(missing {e})")
            else:
                if not isinstance(val, (int, float)) or \
                        isinstance(val, bool):
                    errors.append(
                        f"{path.name}: primary_metric {pm['path']!r} must "
                        f"be a number, got {type(val).__name__}")
    return errors


def check(root: pathlib.Path = ROOT) -> list:
    paths = sorted(root.glob("BENCH_*.json"))
    if not paths:
        return []          # a repo with no artifacts yet is not broken
    return [e for p in paths for e in check_file(p)]


def compare(fresh_path: pathlib.Path, baseline_path: pathlib.Path,
            threshold: float = 0.2) -> list:
    """Regression check on the primary metric; returns error strings."""
    # explicit existence check first: a missing file would otherwise
    # surface as an OSError dressed up as "unparseable JSON", which points
    # the reader at the artifact's contents instead of its absence
    missing = [f"{role} artifact not found: {p} — run the benchmark "
               "first (baselines are committed at the repo root)"
               for role, p in (("fresh", fresh_path),
                               ("baseline", baseline_path))
               if not p.is_file()]
    if missing:
        return missing
    if fresh_path.resolve() == baseline_path.resolve():
        # benchmarks write to cwd: rerunning one at the repo root
        # overwrites the committed baseline in place, and a self-compare
        # would vacuously pass — run the fresh bench in another directory
        return [f"{fresh_path.name}: fresh and baseline are the SAME file "
                f"({fresh_path.resolve()}); a self-compare cannot gate "
                "anything"]
    errors = check_file(fresh_path) + check_file(baseline_path)
    if errors:
        return errors
    fresh = json.loads(fresh_path.read_text())
    baseline = json.loads(baseline_path.read_text())
    pm = fresh.get("primary_metric") or baseline.get("primary_metric")
    if pm is None:
        return [f"{fresh_path.name}: neither fresh nor baseline declares "
                "'primary_metric' — nothing to gate on"]
    vals = {}
    for role, p, data in (("fresh", fresh_path, fresh),
                          ("baseline", baseline_path, baseline)):
        try:
            vals[role] = float(resolve_path(data, pm["path"]))
        except KeyError as e:
            return [f"{p.name}: {role} artifact lacks primary_metric "
                    f"path {pm['path']!r} (missing component {e}) — "
                    "was it produced by an older benchmark version?"]
    new, old = vals["fresh"], vals["baseline"]
    hib = pm["higher_is_better"]
    if old == 0:
        # sign must follow the direction of movement, or a drop from a
        # zero baseline would read as +inf and pass a higher-is-better gate
        change = 0.0 if new == old else math.copysign(float("inf"),
                                                      new - old)
    else:
        change = (new - old) / abs(old)
    regressed = change < -threshold if hib else change > threshold
    direction = "higher" if hib else "lower"
    print(f"{fresh_path.name}: {pm['path']} baseline={old:.6g} "
          f"fresh={new:.6g} change={change * 100:+.2f}% "
          f"({direction} is better, threshold ±{threshold * 100:.0f}%)")
    if regressed:
        return [f"{fresh_path.name}: primary metric {pm['path']!r} "
                f"regressed {change * 100:+.2f}% vs {baseline_path.name} "
                f"(allowed: {threshold * 100:.0f}%)"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare", nargs="+", metavar=("FRESH", "BASELINE"),
                    help="regression-gate FRESH against BASELINE (default "
                         "baseline: the same filename in the repo root)")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="allowed fractional regression of the primary "
                         "metric (default 0.2 = 20%%)")
    args = ap.parse_args(argv)

    if args.compare:
        if len(args.compare) > 2:
            ap.error("--compare takes FRESH and at most one BASELINE")
        fresh = pathlib.Path(args.compare[0])
        baseline = pathlib.Path(args.compare[1]) if len(args.compare) == 2 \
            else ROOT / fresh.name
        errors = compare(fresh, baseline, threshold=args.threshold)
        for e in errors:
            print(f"BENCH COMPARE: {e}", file=sys.stderr)
        if not errors:
            print(f"bench regression gate OK ({fresh.name})")
        return 1 if errors else 0

    errors = check()
    for e in errors:
        print(f"BENCH CHECK: {e}", file=sys.stderr)
    if not errors:
        n = len(sorted(ROOT.glob("BENCH_*.json")))
        print(f"bench envelope OK ({n} artifacts)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
