#!/usr/bin/env python
"""Benchmark-artifact check: every `BENCH_*.json` in the repo root must
parse and carry the shared envelope

    {"name": <non-empty str>, "config": <dict>, "results": <non-empty dict>}

so downstream tooling (CI trend lines, cross-PR diffs) can consume any
artifact without per-benchmark knowledge. Writers: see
`benchmarks/input_pipeline.py`, `benchmarks/strategy_hierarchy.py`,
`benchmarks/shard_ownership.py`.

Run directly (exits non-zero listing violations) or through
scripts/check.sh / `.github/workflows/ci.yml`.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

ENVELOPE = {"name": str, "config": dict, "results": dict}


def check_file(path: pathlib.Path) -> list:
    errors = []
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unparseable JSON ({e})"]
    if not isinstance(data, dict):
        return [f"{path.name}: top level must be an object, "
                f"got {type(data).__name__}"]
    for key, typ in ENVELOPE.items():
        if key not in data:
            errors.append(f"{path.name}: missing envelope key {key!r}")
        elif not isinstance(data[key], typ):
            errors.append(f"{path.name}: {key!r} must be "
                          f"{typ.__name__}, got "
                          f"{type(data[key]).__name__}")
    if isinstance(data.get("name"), str) and not data["name"]:
        errors.append(f"{path.name}: 'name' must be non-empty")
    if isinstance(data.get("results"), dict) and not data["results"]:
        errors.append(f"{path.name}: 'results' must be non-empty")
    return errors


def check(root: pathlib.Path = ROOT) -> list:
    paths = sorted(root.glob("BENCH_*.json"))
    if not paths:
        return []          # a repo with no artifacts yet is not broken
    return [e for p in paths for e in check_file(p)]


def main() -> int:
    errors = check()
    for e in errors:
        print(f"BENCH CHECK: {e}", file=sys.stderr)
    if not errors:
        n = len(sorted(ROOT.glob("BENCH_*.json")))
        print(f"bench envelope OK ({n} artifacts)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
