#!/usr/bin/env python
"""Docs link-check: every code path referenced from docs/*.md must exist.

Two kinds of references are validated in backtick spans:
  - file paths (`src/repro/core/dpmr.py`, `scripts/check.sh`,
    `benchmarks/convergence.py`, optionally with a `::symbol` suffix)
  - dotted module paths (`repro.api.strategies`, resolved under src/;
    trailing attribute components are allowed once the module resolves)

Run directly (exits non-zero listing broken references) or through
scripts/check.sh; tests/test_docs.py runs it in the tier-1 suite.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

FILE_REF = re.compile(
    r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+\.(?:py|md|sh|json))"
    r"(?:::[A-Za-z0-9_.]+)?`")
MODULE_REF = re.compile(r"`(repro(?:\.[a-z_][a-z0-9_]*)+)`")


def _module_exists(dotted: str) -> bool:
    """True iff a leading prefix of `dotted` resolves to a module under
    src/ (the remaining components may be attributes)."""
    base = ROOT / "src"
    parts = dotted.split(".")
    for depth, comp in enumerate(parts):
        if (base / comp).is_dir():
            base = base / comp
            continue
        if (base / (comp + ".py")).exists():
            return True
        # unresolved component: fine only if at least repro.<x> resolved
        return depth >= 2
    return True     # the whole dotted path is a package


def check(root: pathlib.Path = ROOT) -> list:
    errors = []
    docs = sorted((root / "docs").glob("*.md"))
    if not docs:
        return [f"no docs found under {root / 'docs'}"]
    for doc in docs:
        text = doc.read_text()
        for m in FILE_REF.finditer(text):
            if not (root / m.group(1)).exists():
                errors.append(f"{doc.name}: missing file {m.group(1)}")
        for m in MODULE_REF.finditer(text):
            if not _module_exists(m.group(1)):
                errors.append(f"{doc.name}: unresolvable module "
                              f"{m.group(1)}")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"DOCS LINK-CHECK: {e}", file=sys.stderr)
    if not errors:
        print(f"docs link-check OK "
              f"({len(sorted((ROOT / 'docs').glob('*.md')))} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
