"""Table 1 analogue: per-STAGE cost vs shard count.

The paper measures wall-minutes per map-reduce stage at (33,25)/(100,75)/
(200,150) mappers,reducers and finds ~linear speedup. Wall time on a 1-core
CPU host is meaningless, so we reproduce the scaling LAW the table
demonstrates: per-device work (FLOPs) and per-device shuffle bytes of every
DPMR stage, as a function of shard count P, derived from the per-stage
buffer math (identical to what the engine executes). Linear speedup ==
per-device cost ~ 1/P at fixed problem size, with the shuffle bytes bounded
by capacity x P (constant) — which the table shows.

Also validated numerically: the multi-device engine produces bit-identical
parameters to the 1-device run (tests/test_multidevice.py), so the per-stage
cost model is the only thing separating P=1 from P=256.
"""
from __future__ import annotations


from repro.configs.base import DPMRConfig
from repro.core import dpmr


def stage_costs(cfg: DPMRConfig, global_batch: int, p: int,
                cap_factor: float = 4.0, pods: int = 1) -> dict:
    """Per-device per-iteration cost model for each DPMR stage.

    Every collective stage's `shuffle_bytes` (total) is split into
    `dcn_bytes` (crossing the `pod` outer tier — the (P - P/pods)/P
    fraction of a flat collective's traffic addressed to other pods) and
    the implicit ICI remainder, matching the strategies' two-tier
    `bytes_per_device` contract."""
    k = cfg.max_features_per_sample
    b_loc = global_batch // p
    n = b_loc * k                       # feature slots per device
    f_loc = -(-cfg.num_features // p)
    cap = dpmr.capacity_for_shards(cfg, b_loc, p, cap_factor)
    pi = p // max(pods, 1)
    dcn = (p - pi) / p                  # cross-pod traffic fraction

    def coll(byts):
        return {"shuffle_bytes": byts, "dcn_bytes": int(byts * dcn)}

    stages = {
        # invertDocuments: sort-by-feature = O(n log n) compare ops, local
        "invertDocuments": {"flops": n * max(n.bit_length(), 1),
                            "shuffle_bytes": 0, "dcn_bytes": 0},
        # distributeParameters: request ids + response values, both a2a
        "distributeParameters": {"flops": n, **coll(2 * p * cap * 4)},
        # restoreDocuments: local unsort/gather
        "restoreDocuments": {"flops": n, "shuffle_bytes": 0, "dcn_bytes": 0},
        # computeGradients: fused sigmoid-grad (2nk mul-add) + combiner
        "computeGradients": {"flops": 4 * n, **coll(p * cap * 4)},
        # updateParameters: owner-local SGD/adagrad update
        "updateParameters": {"flops": 2 * f_loc,
                             "shuffle_bytes": 0, "dcn_bytes": 0},
        # hot psum: replicated head gradients, ring all-reduce
        "hotSync": {"flops": cfg.max_hot, **coll(2 * cfg.max_hot * 4)},
    }
    total = {"flops": sum(s["flops"] for s in stages.values()),
             "shuffle_bytes": sum(s["shuffle_bytes"]
                                  for s in stages.values()),
             "dcn_bytes": sum(s["dcn_bytes"] for s in stages.values())}
    return {"stages": stages, "total": total, "cap": cap, "b_loc": b_loc}


def run(global_batch: int = 1 << 16, feature_space: int = 1 << 24,
        pods: int = 1):
    cfg = DPMRConfig(num_features=feature_space, max_features_per_sample=64)
    shard_counts = [32, 64, 128, 256, 512]
    rows = []
    base = None
    for p in shard_counts:
        c = stage_costs(cfg, global_batch, p, pods=pods)
        t = c["total"]
        if base is None:
            base = t
        rows.append({
            "shards": p,
            "pods": pods,
            "flops_per_dev": t["flops"],
            "shuffle_bytes_per_dev": t["shuffle_bytes"],
            "dcn_bytes_per_dev": t["dcn_bytes"],
            "speedup_vs_first": base["flops"] / t["flops"],
            "stages": {k: v for k, v in c["stages"].items()},
        })
    return rows


def _print_rows(rows):
    print(f"{'P':>5s} {'flops/dev':>12s} {'shuffle B/dev':>14s} "
          f"{'DCN B/dev':>12s} {'speedup':>8s} {'linear?':>8s}")
    p0 = rows[0]["shards"]
    for r in rows:
        ideal = r["shards"] / p0
        print(f"{r['shards']:>5d} {r['flops_per_dev']:>12.3e} "
              f"{r['shuffle_bytes_per_dev']:>14.3e} "
              f"{r['dcn_bytes_per_dev']:>12.3e} "
              f"{r['speedup_vs_first']:>8.2f} "
              f"{r['speedup_vs_first']/ideal:>7.0%}")


def main():
    rows = run()
    print("== single pod (all shuffle bytes on ICI) ==")
    _print_rows(rows)
    rows2 = run(pods=2)
    print("\n== two pods (flat collectives: cross-pod fraction on DCN) ==")
    _print_rows(rows2)
    return rows + rows2


if __name__ == "__main__":
    main()
