"""Top-k sparsified & overlap-aware exchange benchmark.

Quantifies the two PR-5 strategies against the paper-faithful `a2a`:

  wire         two-tier (ICI/DCN) bytes per device per step of EVERY
               registered strategy at the paper's full-batch regime on the
               (2, 16, 16) production mesh. Headline: `topk_reduce` cuts
               the reverse-shuffle wire volume cap -> 2k pairs on both
               tiers; `overlap_a2a` matches `a2a` byte-for-byte (it buys
               schedule, not volume).
  topk         the k sweep — per `topk_frac`: the analytic wire reduction
               (reduce leg and total) and the measured convergence parity
               vs `a2a` on an SGD run (error feedback at work). Asserted
               here and in the acceptance gate: at the default
               `topk_frac=0.25` the final loss lands within 0.1% of a2a.
  overlap      `overlap_a2a` bit-identity to `a2a` (parameters compared
               after a shared batch stream) and the host-emulation step
               timing of both (micro-chunking is a scheduling property;
               on real ICI the async chunks hide behind the inference
               matmul, on the CPU emulation the ratio should sit near 1x
               — the bit-identity is the load-bearing claim).

Emits `BENCH_strategy_overlap.json` (shared envelope: `name` / `config` /
`results`, validated by `scripts/check_bench.py`) with a `primary_metric`
declaration consumed by `scripts/check_bench.py --compare`, the nightly CI
bench-regression gate. The primary metric is the ANALYTIC total-wire
reduction of topk_reduce at the default fraction — deterministic, so the
20% regression threshold flags real wire-model changes, not runner noise.

Run: PYTHONPATH=src python benchmarks/strategy_overlap.py
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.api import DPMREngine, get_strategy, list_strategies
from repro.api.strategies import StrategyContext
from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.optim import compression

# paper-regime headline geometry: 2-pod production mesh, full-batch GD
P, PODS = 512, 2
GLOBAL_BATCH = 1 << 24
K = 64
FEATURES = 1 << 30

# the measured convergence/bit-identity runs (host mesh, SGD regime)
RUN_FEATURES = 1 << 14
RUN_STEPS = 40
RUN_BATCH = 256

FRACS = (0.05, 0.1, 0.25, 0.5, 1.0)


def _ctx(topk_frac: float = 0.25) -> StrategyContext:
    cfg = DPMRConfig(num_features=FEATURES, max_features_per_sample=K,
                     topk_frac=topk_frac)
    cap = dpmr.capacity_for_shards(cfg, GLOBAL_BATCH // P, P)
    return StrategyContext(axes=(), num_shards=P,
                           block_size=-(-FEATURES // P), capacity=cap,
                           outer_shards=PODS, topk_frac=topk_frac)


def wire_rows() -> list:
    ctx = _ctx()
    rows = []
    for name in list_strategies():
        wb = get_strategy(name).bytes_per_device(ctx)
        rows.append({"strategy": name, "shards": P, "pods": PODS,
                     "capacity": ctx.capacity,
                     "inner_bytes": int(wb.inner),
                     "outer_bytes": int(wb.outer),
                     "total_bytes": int(wb.total)})
    return rows


def topk_wire_sweep() -> list:
    """Analytic cap -> 2k reduction per topk_frac, both tiers."""
    a2a = get_strategy("a2a").bytes_per_device(_ctx())
    a2a_reduce = a2a.total // 3          # one of the three (P, cap) buffers
    rows = []
    for frac in FRACS:
        ctx = _ctx(frac)
        wb = get_strategy("topk_reduce").bytes_per_device(ctx)
        k = compression.topk_count(ctx.capacity, frac)
        reduce_bytes = wb.total - (2 * a2a_reduce)      # minus fwd buffers
        rows.append({
            "topk_frac": frac, "capacity": ctx.capacity, "k": k,
            "inner_bytes": int(wb.inner), "outer_bytes": int(wb.outer),
            "total_bytes": int(wb.total),
            "reduce_bytes": int(reduce_bytes),
            "reduce_reduction_x": a2a_reduce / reduce_bytes,
            "total_reduction_x": a2a.total / wb.total,
        })
    return rows


def _engine(distribution: str, topk_frac: float = 0.25) -> DPMREngine:
    cfg = DPMRConfig(num_features=RUN_FEATURES, max_features_per_sample=32,
                     max_hot=64, optimizer="adagrad", learning_rate=2.0,
                     distribution=distribution, topk_frac=topk_frac)
    return DPMREngine(cfg, make_host_mesh(1, 1))


def _batches(steps: int):
    return get_source("zipf_sparse", batch_size=RUN_BATCH,
                      num_features=RUN_FEATURES, features_per_sample=32,
                      signal_features=512, seed=0).iter_batches(limit=steps)


def topk_convergence_sweep() -> dict:
    """Final SGD loss per topk_frac vs a2a — the loss-vs-k trade."""
    base_eng = _engine("a2a")
    base_hist = base_eng.fit_sgd(_batches(RUN_STEPS))
    base = float(np.mean([h["loss"] for h in base_hist[-5:]]))
    rows = []
    for frac in FRACS:
        eng = _engine("topk_reduce", frac)
        hist = eng.fit_sgd(_batches(RUN_STEPS))
        loss = float(np.mean([h["loss"] for h in hist[-5:]]))
        rows.append({"topk_frac": frac, "final_loss": loss,
                     "loss_vs_a2a_pct": abs(loss - base) / base * 100,
                     "carry_l1": float(np.abs(
                         np.asarray(eng.state.strat)).sum())})
    at_default = next(r for r in rows if r["topk_frac"] == 0.25)
    assert at_default["loss_vs_a2a_pct"] < 0.1, (
        "topk_reduce at the default topk_frac=0.25 must land within 0.1% "
        "of a2a's final loss (error feedback)", at_default)
    # teeth: at this run geometry k >= live slots at frac >= 0.25 (nothing
    # is dropped, so the 0.1% gate alone would also pass with a broken
    # error-feedback path). Require that the aggressive fractions REALLY
    # sparsified (live residual) and that error feedback still held the
    # loss close — this is where a dead re-injection path shows up.
    sparsifying = [r for r in rows if r["topk_frac"] <= 0.1]
    assert sparsifying and all(r["carry_l1"] > 0 for r in sparsifying), (
        "the sweep must include fractions that actually drop slots",
        rows)
    assert all(r["loss_vs_a2a_pct"] < 2.0 for r in sparsifying), (
        "error feedback must keep even aggressive sparsification within "
        "2% of a2a's final loss", sparsifying)
    return {"a2a_final_loss": base, "sweep": rows,
            "loss_pct_at_default": at_default["loss_vs_a2a_pct"]}


def overlap_rows(steps: int = 20) -> dict:
    """Bit-identity + host-emulation step timing of overlap_a2a vs a2a."""
    out = {}
    state = {}
    for dist in ("a2a", "overlap_a2a"):
        eng = _engine(dist)
        eng.fit_sgd(_batches(2))                 # compile + warm up
        t0 = time.perf_counter()
        eng.fit_sgd(_batches(steps))
        out[f"steps_per_s_{dist}"] = steps / (time.perf_counter() - t0)
        state[dist] = np.asarray(eng.state.cold)
    bit_identical = bool(np.array_equal(state["a2a"], state["overlap_a2a"]))
    assert bit_identical, "overlap_a2a must be bit-identical to a2a"
    out["bit_identical"] = bit_identical
    out["speedup_x"] = (out["steps_per_s_overlap_a2a"]
                        / out["steps_per_s_a2a"])
    return out


def run(write_json: bool = True) -> dict:
    wire = wire_rows()
    by_name = {r["strategy"]: r for r in wire}
    assert by_name["overlap_a2a"]["total_bytes"] == \
        by_name["a2a"]["total_bytes"], (
        "overlap_a2a trades schedule, not bytes", by_name)
    assert by_name["topk_reduce"]["total_bytes"] < \
        by_name["a2a"]["total_bytes"], (
        "topk_reduce must cut total wire bytes at the default fraction",
        by_name)
    topk_wire = topk_wire_sweep()
    at_default = next(r for r in topk_wire if r["topk_frac"] == 0.25)
    out = {
        "name": "strategy_overlap",
        "config": {"shards": P, "pods": PODS, "global_batch": GLOBAL_BATCH,
                   "features": FEATURES, "features_per_sample": K,
                   "run_features": RUN_FEATURES, "run_steps": RUN_STEPS,
                   "run_batch": RUN_BATCH, "fracs": list(FRACS)},
        # consumed by scripts/check_bench.py --compare (nightly CI gate):
        # the analytic topk wire reduction at the default fraction —
        # deterministic, so a >20% drop means the wire model changed
        "primary_metric": {"path": "results.topk_wire_reduction_x",
                           "higher_is_better": True},
        "results": {
            "wire": wire,
            "topk_wire_reduction_x": at_default["total_reduction_x"],
            "topk_wire_sweep": topk_wire,
            "topk_convergence": topk_convergence_sweep(),
            "overlap": overlap_rows(),
        },
    }
    if write_json:
        with open("BENCH_strategy_overlap.json", "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    out = run()
    res = out["results"]
    print(f"{'strategy':>18s} {'ICI B/dev':>12s} {'DCN B/dev':>12s}")
    for r in res["wire"]:
        print(f"{r['strategy']:>18s} {r['inner_bytes']:>12.3e} "
              f"{r['outer_bytes']:>12.3e}")
    print("\ntopk_reduce wire sweep (reduce leg cap -> 2k pairs):")
    for r in res["topk_wire_sweep"]:
        print(f"  frac={r['topk_frac']:<5} k={r['k']:>6d} "
              f"reduce x{r['reduce_reduction_x']:.2f} "
              f"total x{r['total_reduction_x']:.2f}")
    print("\ntopk_reduce convergence vs a2a:")
    for r in res["topk_convergence"]["sweep"]:
        print(f"  frac={r['topk_frac']:<5} loss {r['final_loss']:.4f} "
              f"({r['loss_vs_a2a_pct']:.4f}% off a2a) "
              f"carry L1 {r['carry_l1']:.3f}")
    ov = res["overlap"]
    print(f"\noverlap_a2a: bit-identical={ov['bit_identical']} "
          f"speedup x{ov['speedup_x']:.3f} (host emulation)")
    print("wrote BENCH_strategy_overlap.json")
    return out


if __name__ == "__main__":
    main()
