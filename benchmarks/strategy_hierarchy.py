"""Hierarchy & compression benchmark: the two-tier wire model of every
registered strategy on the multi-pod production geometry, plus convergence
parity of the new strategies against the paper-faithful `a2a`.

Emits `BENCH_strategy_hierarchy.json` (shared envelope: `name` / `config` /
`results`, validated by `scripts/check_bench.py`) whose results carry

  wire         per-strategy inner (ICI) / outer (DCN) bytes per device per
               step at the paper's full-batch regime on the (2, 16, 16)
               production mesh (P=512, Po=2). The headline claim recorded
               here: `hier_a2a` crosses the DCN tier with strictly fewer
               bytes than flat `a2a` — it ships the table block (mirror +
               per-pod partials) instead of the shuffled request volume.
  crossover    the same sweep over |F|, showing where the table block
               outgrows the request volume and flat a2a wins DCN again
               (hier_a2a trades ICI volume for that DCN reduction).
  convergence  final loss of each strategy on the Fig.-1 convergence
               benchmark (benchmarks/convergence.py), with parity vs a2a.
               The exact strategies are bit-identical; compressed_reduce
               must land within 1% (error feedback at work).

Run: PYTHONPATH=src python benchmarks/strategy_hierarchy.py
"""
from __future__ import annotations

import json

from repro.api import get_strategy, list_strategies
from repro.api.strategies import StrategyContext
from repro.configs.base import DPMRConfig
from repro.core import dpmr

# paper-regime headline geometry: 2-pod production mesh, full-batch GD
P, PODS = 512, 2
GLOBAL_BATCH = 1 << 24
K = 64
FEATURES = 1 << 30


def _ctx(features: int, p: int = P, pods: int = PODS,
         batch: int = GLOBAL_BATCH) -> StrategyContext:
    cfg = DPMRConfig(num_features=features, max_features_per_sample=K)
    cap = dpmr.capacity_for_shards(cfg, batch // p, p)
    return StrategyContext(axes=(), num_shards=p,
                           block_size=-(-features // p), capacity=cap,
                           outer_shards=pods)


def wire_rows(features: int = FEATURES) -> list:
    ctx = _ctx(features)
    rows = []
    for name in list_strategies():
        wb = get_strategy(name).bytes_per_device(ctx)
        rows.append({"strategy": name, "features": features,
                     "shards": P, "pods": PODS, "capacity": ctx.capacity,
                     "inner_bytes": int(wb.inner),
                     "outer_bytes": int(wb.outer),
                     "total_bytes": int(wb.total)})
    return rows


def crossover_rows() -> list:
    """DCN bytes of hier_a2a vs flat a2a over |F|: hier wins while the
    per-device table block stays below the shuffled request volume."""
    rows = []
    for logf in (24, 27, 30, 33):
        ctx = _ctx(1 << logf)
        a2a = get_strategy("a2a").bytes_per_device(ctx)
        hier = get_strategy("hier_a2a").bytes_per_device(ctx)
        rows.append({"features": 1 << logf,
                     "a2a_outer": int(a2a.outer),
                     "hier_outer": int(hier.outer),
                     "hier_wins_dcn": bool(hier.outer < a2a.outer)})
    return rows


def convergence_parity(iterations: int = 6) -> dict:
    try:
        from benchmarks import convergence      # harness import (run.py)
    except ImportError:
        import convergence                      # direct script execution

    out = {}
    for name in ("a2a", "allgather", "psum_scatter", "hier_a2a",
                 "compressed_reduce"):
        hist = convergence.run(iterations=iterations, distribution=name)
        out[name] = {"final_loss": hist[-1]["loss"],
                     "final_f_avg": hist[-1]["f_avg"]}
    base = out["a2a"]["final_loss"]
    for name, rec in out.items():
        rec["loss_vs_a2a_pct"] = abs(rec["final_loss"] - base) / base * 100
    return out


def run(write_json: bool = True, iterations: int = 6) -> dict:
    wire = wire_rows()
    by_name = {r["strategy"]: r for r in wire}
    assert by_name["hier_a2a"]["outer_bytes"] < \
        by_name["a2a"]["outer_bytes"], (
        "hier_a2a must cross DCN with strictly fewer bytes than flat a2a "
        "at the headline geometry", by_name)
    # shared BENCH envelope (scripts/check_bench.py): name/config/results
    results = {
        "name": "strategy_hierarchy",
        "config": {"shards": P, "pods": PODS,
                   "global_batch": GLOBAL_BATCH,
                   "features": FEATURES, "features_per_sample": K},
        "results": {
            "wire": wire,
            "crossover": crossover_rows(),
            "convergence": convergence_parity(iterations),
        },
    }
    if write_json:
        with open("BENCH_strategy_hierarchy.json", "w") as fh:
            json.dump(results, fh, indent=2)
    return results


def main():
    res = run()["results"]
    print(f"{'strategy':>18s} {'ICI B/dev':>12s} {'DCN B/dev':>12s}")
    for r in res["wire"]:
        print(f"{r['strategy']:>18s} {r['inner_bytes']:>12.3e} "
              f"{r['outer_bytes']:>12.3e}")
    print("\nDCN crossover (a2a vs hier_a2a outer bytes):")
    for r in res["crossover"]:
        print(f"  |F|=2^{r['features'].bit_length() - 1}: "
              f"a2a {r['a2a_outer']:.3e}  hier {r['hier_outer']:.3e}  "
              f"hier wins: {r['hier_wins_dcn']}")
    print("\nconvergence parity vs a2a (final loss):")
    for name, rec in res["convergence"].items():
        print(f"  {name:>18s} loss {rec['final_loss']:.4f} "
              f"({rec['loss_vs_a2a_pct']:.3f}% off a2a), "
              f"F {rec['final_f_avg']:.3f}")
    print("wrote BENCH_strategy_hierarchy.json")
    return res


if __name__ == "__main__":
    main()
