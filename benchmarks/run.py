"""Benchmark harness entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall-clock microbenchmarks are
measured on this host's CPU (meaningful relatively, not as TPU numbers);
derived columns carry the paper-relevant quantity (speedup linearity,
convergence F, overflow, byte ratios). Roofline terms come from the dry-run
artifacts if present (results/probes + results/dryrun).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_us(fn, *args, iters: int = 5, warmup: int = 2):
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
        jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_table1_stage_scaling():
    """Paper Table 1: per-stage scaling with shard count."""
    from benchmarks import stage_scaling

    rows = stage_scaling.run()
    p0 = rows[0]["shards"]
    worst = min(r["speedup_vs_first"] / (r["shards"] / p0) for r in rows)
    print(f"table1_stage_scaling,0,linearity={worst:.3f}")
    return rows


def bench_fig1_convergence():
    """Paper Fig 1: P/R/F convergence over iterations."""
    from benchmarks import convergence

    t0 = time.perf_counter()
    hist = convergence.run(iterations=8)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"fig1_convergence,{dt/8:.0f},f_avg_final={hist[-1]['f_avg']:.3f}")
    return hist


def bench_sec4_hot_sharding():
    from benchmarks import hot_sharding

    rows = hot_sharding.run()
    base = rows[0]["imbalance"]
    best = min(r["imbalance"] for r in rows[1:])
    print(f"sec4_hot_sharding,0,owner_imbalance_{base:.2f}->{best:.2f}")
    return rows


def bench_a2a_vs_allgather():
    from benchmarks import a2a_vs_allgather

    rows = a2a_vs_allgather.run()
    print(f"a2a_vs_allgather,0,ratio_at_2^33={rows[-1]['ratio']:.0f}x")
    return rows


def bench_dpmr_step():
    """Wall time of one DPMR SGD step (CPU, relative use only)."""
    from repro.api import DPMREngine, get_source
    from repro.configs.base import DPMRConfig
    from repro.launch.mesh import make_host_mesh

    src = get_source("zipf_sparse", batch_size=1024, num_features=1 << 16,
                     features_per_sample=32)
    cfg = DPMRConfig(num_features=1 << 16, max_features_per_sample=32)
    engine = DPMREngine(cfg, make_host_mesh(1, 1))
    fns = engine.step_fns(1024)
    b = engine.put_batch(src.batch(0))

    def step():
        # train_step donates the state; thread the returned one so every
        # timed call consumes a live buffer (engine.state stays current)
        engine.state, _ = fns.train_step(engine.state, b)
    us = _time_us(step)
    print(f"dpmr_sgd_step_b1024,{us:.0f},tokens_per_s="
          f"{1024 / (us / 1e6):.0f}")


def bench_input_pipeline():
    """Loader throughput + prefetch overlap (see benchmarks/input_pipeline)."""
    from benchmarks import input_pipeline

    res = input_pipeline.run(quick=True, write_json=False)
    print(f"input_pipeline,0,overlap_speedup="
          f"{res['results']['fit_sgd']['speedup']:.2f}x")


def bench_shard_ownership():
    """Chunk-ownership locality: files opened per host vs stride baseline."""
    from benchmarks import shard_ownership

    res = shard_ownership.run(num_chunks=8, batches_per_chunk=4,
                              batch_size=64, hosts=(1, 4),
                              write_json=False)
    row = res["results"]["sweep"][-1]
    print(f"shard_ownership,0,opens_per_host="
          f"{row['stride_baseline']['max_files_opened']}->"
          f"{row['ownership']['max_files_opened']}@H={row['hosts']}")


def bench_strategy_overlap():
    """Top-k wire reduction + overlap bit-identity (see strategy_overlap)."""
    from benchmarks import strategy_overlap

    rows = strategy_overlap.topk_wire_sweep()
    at_default = next(r for r in rows if r["topk_frac"] == 0.25)
    ov = strategy_overlap.overlap_rows(steps=5)
    print(f"strategy_overlap,0,topk_total_wire_x"
          f"{at_default['total_reduction_x']:.2f}"
          f"_overlap_bit_identical={ov['bit_identical']}")


def bench_kernels():
    """Interpret-mode kernel calls vs jnp oracle (correct-by-construction
    check is in tests; here: relative CPU wall time)."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(512, 64)).astype(np.float32))
    theta = jnp.asarray(rng.normal(size=(512, 64)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 2, size=(512,)).astype(np.int32))
    us = _time_us(lambda: ops.sigmoid_grad(vals, theta, y, impl="jnp"))
    print(f"kernel_sigmoid_grad_jnp,{us:.0f},B=512xK=64")

    ids = jnp.asarray(np.sort(rng.integers(0, 997, size=4096))
                      .astype(np.int32))
    g = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    us = _time_us(lambda: ops.segment_sum_sorted(ids, g, impl="jnp"))
    print(f"kernel_segment_sum_jnp,{us:.0f},N=4096")

    q = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 32)).astype(np.float32))
    us = _time_us(lambda: ops.flash_attention(q, k, v, impl="jnp"))
    print(f"kernel_flash_attention_jnp,{us:.0f},S=256_GQA4:2")


def bench_train_step():
    """Smoke-scale LM train step wall time (CPU)."""
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.data import get_source
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.train import trainer

    from repro import compat

    mesh = make_host_mesh(1, 1)
    cfg = registry.smoke_config("granite-8b")
    spec = registry.get_spec("granite-8b")
    tc = TrainConfig()
    pc = ParallelConfig()
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        src = get_source("lm_markov", vocab_size=cfg.vocab_size, seq_len=64,
                         batch_size=8)
        b = jax.tree.map(jnp.asarray, src.batch(0))
        us = _time_us(lambda: step(state, b))
    toks = 8 * 64
    print(f"lm_train_step_smoke,{us:.0f},tokens_per_s={toks/(us/1e6):.0f}")


def bench_roofline():
    """Roofline table from the dry-run artifacts (if present)."""
    import os

    if not (os.path.isdir("results/probes")
            and os.path.isdir("results/dryrun")):
        print("roofline,0,skipped_no_dryrun_artifacts")
        return
    from benchmarks import roofline

    rows = roofline.analyze()
    if not rows:
        print("roofline,0,no_probe_results_yet")
        return
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    print(f"roofline_cells,{len(rows)},worst={worst['arch']}:"
          f"{worst['shape']}@{100*worst['roofline_fraction']:.0f}%")


def main() -> None:
    print("name,us_per_call,derived")
    bench_table1_stage_scaling()
    bench_fig1_convergence()
    bench_sec4_hot_sharding()
    bench_a2a_vs_allgather()
    bench_dpmr_step()
    bench_input_pipeline()
    bench_shard_ownership()
    bench_strategy_overlap()
    bench_kernels()
    bench_train_step()
    bench_roofline()


if __name__ == "__main__":
    main()
