"""Input-pipeline benchmark: loader throughput + prefetch overlap.

Measures the data plane three ways and emits `BENCH_input_pipeline.json`:

  loader     raw ShardedLoader batches/sec — synthetic `zipf_sparse` vs
             on-disk `file_sparse` chunks, prefetch off vs on. Isolates
             host batch synthesis / chunk-file reads + device placement.
  fit_sgd    end-to-end `DPMREngine.fit_sgd` steps/sec — the legacy
             synchronous path (per-batch synthesis + device_put serialized
             with the step) vs the prefetching loader, same batches. This
             is the number the tentpole claims: with prefetch, host batch
             synthesis and H2D overlap the training step, so loader-fed
             steps/sec must be >= the synchronous path.

    PYTHONPATH=src python benchmarks/input_pipeline.py
    PYTHONPATH=src python benchmarks/input_pipeline.py --steps 80 \
        --batch 8192
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from repro.api import DPMREngine, ShardedLoader, get_source, write_file_corpus
from repro.configs.base import DPMRConfig
from repro.launch.mesh import make_host_mesh


def _loader_throughput(loader, n: int) -> float:
    """Batches/sec draining `n` batches (includes placement)."""
    import jax

    it = loader.batches(n)
    first = next(it)              # warm the source/thread outside the clock
    jax.block_until_ready(list(first.values()))
    t0 = time.perf_counter()
    got = 1
    for b in it:
        jax.block_until_ready(list(b.values()))
        got += 1
    return (got - 1) / (time.perf_counter() - t0)


def _fit_sgd_throughput(cfg, mesh, data_fn, warm_batch, steps: int) -> float:
    """End-to-end fit_sgd steps/sec, compile excluded: warm ON THE TIMED
    ENGINE (make_step_fns builds fresh jitted closures per engine, so a
    throwaway engine's compile cache would not transfer), then time `steps`
    over the real stream. Both variants warm identically."""
    eng = DPMREngine(cfg, mesh)
    eng.fit_sgd([warm_batch])
    t0 = time.perf_counter()
    eng.fit_sgd(data_fn(), steps)
    return steps / (time.perf_counter() - t0)


def run(steps: int = 40, batch: int = 4096, log2_features: int = 18,
        quick: bool = False, write_json: bool = True) -> dict:
    if quick:
        steps, batch, log2_features = 10, 1024, 14
    f = 1 << log2_features
    corpus = dict(num_features=f, features_per_sample=64,
                  signal_features=2048)
    cfg = DPMRConfig(num_features=f, max_features_per_sample=64,
                     learning_rate=1.0, max_hot=64, optimizer="sgd")
    mesh = make_host_mesh(1, 1)

    def zipf(num_batches=None):
        return get_source("zipf_sparse", batch_size=batch,
                          num_batches=num_batches, **corpus)

    # shared BENCH envelope (scripts/check_bench.py): name/config/results
    out = {"name": "input_pipeline",
           "config": {"steps": steps, "batch": batch, "num_features": f},
           "results": {"loader": {}, "fit_sgd": {}}}
    results = out["results"]

    # -- raw loader throughput: synthetic vs file, prefetch off/on ---------
    tmp = tempfile.mkdtemp(prefix="repro_input_pipeline_")
    try:
        write_file_corpus(tmp, zipf(steps), batches_per_chunk=8)
        for name, make_src in (("zipf_sparse", lambda: zipf(steps)),
                               ("file_sparse",
                                lambda: get_source("file_sparse",
                                                   directory=tmp))):
            for depth in (0, 2):
                loader = ShardedLoader(make_src(), mesh, prefetch=depth)
                bps = _loader_throughput(loader, steps)
                results["loader"][f"{name}_prefetch{depth}"] = round(bps, 2)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # -- end-to-end: synchronous legacy path vs prefetching loader ---------
    warm_batch = zipf().batch(0)
    sync_sps = _fit_sgd_throughput(
        cfg, mesh, lambda: zipf().iter_batches(), warm_batch, steps)
    loader_sps = _fit_sgd_throughput(
        cfg, mesh, lambda: ShardedLoader(zipf(), mesh, prefetch=2),
        warm_batch, steps)
    results["fit_sgd"] = {
        "sync_steps_per_s": round(sync_sps, 2),
        "prefetch_steps_per_s": round(loader_sps, 2),
        "speedup": round(loader_sps / sync_sps, 3),
        "samples_per_s_prefetch": round(loader_sps * batch, 0),
    }

    if write_json:
        with open("BENCH_input_pipeline.json", "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--log2-features", type=int, default=18)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    res = run(steps=args.steps, batch=args.batch,
              log2_features=args.log2_features, quick=args.quick)
    print("name,batches_per_s")
    for k, v in res["results"]["loader"].items():
        print(f"loader_{k},{v}")
    fs = res["results"]["fit_sgd"]
    print(f"fit_sgd_sync,{fs['sync_steps_per_s']}")
    print(f"fit_sgd_prefetch,{fs['prefetch_steps_per_s']}")
    print(f"overlap_speedup,{fs['speedup']}x")
    print("wrote BENCH_input_pipeline.json")


if __name__ == "__main__":
    main()
