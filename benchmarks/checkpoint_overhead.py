"""Checkpoint overhead benchmark: what the step path pays, sync vs async.

Measures the async-checkpointing tentpole and emits
`BENCH_checkpoint.json` with the shared envelope (`name` / `config` /
`results`):

  ledger      DETERMINISTIC byte ledger of the step path. A synchronous
              save blocks training on the device->host gather PLUS the
              full serialize+fsync+rename of every array file and the
              manifest; an async save blocks on the gather only (the
              snapshot that makes donation safe) and ships the bytes
              from a background thread. Both sides are exact functions
              of the state pytree (leaf nbytes; actual on-disk file
              sizes from a real save), so the reduction ratio is the
              `primary_metric` the nightly regression gate compares —
              wall clock on a shared runner is noise, the ledger is not.
  parity      async and sync saves of the same state produce
              byte-identical array files (asserted, recorded) — the
              correctness floor under the performance claim.
  wall_ms     measured save-call latency (sync return vs async return
              vs async background drain) — informational, machine-
              dependent, NOT gated.

    PYTHONPATH=src python benchmarks/checkpoint_overhead.py
    PYTHONPATH=src python benchmarks/checkpoint_overhead.py --features 65536
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.api import DPMREngine
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh


def _engine(features: int, steps: int = 4) -> DPMREngine:
    cfg = DPMRConfig(num_features=features, max_features_per_sample=8)
    eng = DPMREngine(cfg, make_host_mesh(1, 1))
    src = get_source("zipf_sparse", batch_size=32, num_batches=8,
                     num_features=features, features_per_sample=8, seed=5)
    eng.fit_sgd(src.iter_batches(), steps=steps)
    return eng


def _dir_file_bytes(step_dir: str) -> dict:
    sizes = {name: os.path.getsize(os.path.join(step_dir, name))
             for name in sorted(os.listdir(step_dir))}
    return sizes


def bench_ledger(eng: DPMREngine, tmp: str) -> dict:
    """The deterministic step-path ledger, from one real sync save."""
    leaves = jax.tree.leaves(eng.state)
    gather_bytes = int(sum(l.nbytes for l in leaves))
    d = os.path.join(tmp, "ledger")
    step = eng.save(d, block=True)
    step_dir = os.path.join(d, f"step_{step:010d}")
    sizes = _dir_file_bytes(step_dir)
    serialize_bytes = int(sum(sizes.values()))
    sync_blocking = gather_bytes + serialize_bytes
    async_blocking = gather_bytes
    return {
        "num_leaves": len(leaves),
        "gather_bytes": gather_bytes,
        "serialize_bytes": serialize_bytes,
        "manifest_bytes": sizes["manifest.json"],
        "sync_step_path_bytes": sync_blocking,
        "async_step_path_bytes": async_blocking,
        "step_path_bytes_reduction_x": round(
            sync_blocking / async_blocking, 4),
    }


def bench_parity(eng: DPMREngine, tmp: str) -> dict:
    """Async file bytes must equal sync file bytes for the same state."""
    ck_s = Checkpointer(os.path.join(tmp, "sync"))
    ck_a = Checkpointer(os.path.join(tmp, "async"))
    ck_s.save(1, eng.state, block=True)
    ck_a.save(1, eng.state, block=False)
    ck_a.wait()
    d_s = os.path.join(tmp, "sync", "step_0000000001")
    d_a = os.path.join(tmp, "async", "step_0000000001")
    names = sorted(os.listdir(d_s))
    assert names == sorted(os.listdir(d_a)), (names, os.listdir(d_a))
    checked = 0
    for name in names:
        if name == "manifest.json":
            continue
        with open(os.path.join(d_s, name), "rb") as f_s, \
                open(os.path.join(d_a, name), "rb") as f_a:
            assert f_s.read() == f_a.read(), f"{name} differs sync vs async"
        checked += 1
    return {"bit_exact_vs_sync": True, "array_files_checked": checked}


def bench_wall(eng: DPMREngine, tmp: str, repeats: int) -> dict:
    """Measured (informational): how long does save() hold the loop?"""
    sync_ms, async_ms, drain_ms = [], [], []
    for i in range(repeats):
        d = os.path.join(tmp, f"wall_{i}")
        ck = Checkpointer(os.path.join(d, "s"))
        t0 = time.perf_counter()
        ck.save(1, eng.state, block=True)
        sync_ms.append((time.perf_counter() - t0) * 1e3)
        ck = Checkpointer(os.path.join(d, "a"))
        t0 = time.perf_counter()
        ck.save(1, eng.state, block=False)
        async_ms.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        ck.wait()
        drain_ms.append((time.perf_counter() - t0) * 1e3)
    med = lambda xs: round(float(np.median(xs)), 3)  # noqa: E731
    return {"repeats": repeats,
            "sync_save_ms_p50": med(sync_ms),
            "async_save_return_ms_p50": med(async_ms),
            "async_drain_ms_p50": med(drain_ms)}


def run(features: int = 1 << 16, repeats: int = 5,
        write_json: bool = True, out_dir: str = ".") -> dict:
    eng = _engine(features)
    tmp = tempfile.mkdtemp(prefix="ckpt_bench_")
    try:
        results = {
            "ledger": bench_ledger(eng, tmp),
            "parity": bench_parity(eng, tmp),
            "wall_ms": bench_wall(eng, tmp, repeats),
        }
    finally:
        eng.wait_saves()
        shutil.rmtree(tmp, ignore_errors=True)
    out = {
        "name": "checkpoint_overhead",
        "config": {"num_features": features,
                   "max_features_per_sample": 8,
                   "train_steps": 4, "wall_repeats": repeats},
        # deterministic: byte counts from leaf shapes + real npy files —
        # safe to regression-gate at 20% where wall clock would flag noise
        "primary_metric": {
            "path": "results.ledger.step_path_bytes_reduction_x",
            "higher_is_better": True},
        "results": results,
    }
    if write_json:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "BENCH_checkpoint.json"), "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--features", type=int, default=1 << 16)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--out", default=".", help="BENCH_checkpoint.json dir")
    args = ap.parse_args()
    out = run(features=args.features, repeats=args.repeats,
              out_dir=args.out)
    led = out["results"]["ledger"]
    print(f"step path: sync blocks on {led['sync_step_path_bytes']:,} B, "
          f"async on {led['async_step_path_bytes']:,} B "
          f"({led['step_path_bytes_reduction_x']}x less)")
    w = out["results"]["wall_ms"]
    print(f"wall (p50 of {w['repeats']}): sync save "
          f"{w['sync_save_ms_p50']} ms, async return "
          f"{w['async_save_return_ms_p50']} ms, async drain "
          f"{w['async_drain_ms_p50']} ms")
    print(f"parity: {out['results']['parity']['array_files_checked']} "
          f"array files bit-identical sync vs async")
    print(f"wrote {os.path.join(args.out, 'BENCH_checkpoint.json')}")
    return out


if __name__ == "__main__":
    main()
