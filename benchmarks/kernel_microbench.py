"""Kernel micro-benchmark: the Pallas routing hot path vs the XLA chain.

Two kernels sit behind the `kernel_impl` seam (repro.kernels.ops):

  select_pack       `topk_reduce`'s compensate + rank-by-|magnitude| +
                    pack, fused into one VMEM pass per destination row.
                    The XLA chain it replaces is seven ops over the
                    (P, cap) buffer, each an HBM round trip.
  owner_accumulate  the reverse-shuffle scatter-add rebuilt as sort +
                    `segment_sum_sorted` run totals: the owner does ONE
                    memory add per UNIQUE feature instead of one per
                    received slot (scatter-adds serialize on TPU).

This bench prices both ANALYTICALLY — an explicit per-op ledger of HBM
bytes touched at a per-step SGD geometry on the 2-pod production mesh —
and smoke-checks the interpret-mode kernels bit-exactly against
`kernels/ref.py` on a small seeded case. Every number is deterministic
(pure arithmetic + seeded PRNG, no wall clocks), so the nightly
`scripts/check_bench.py --compare` gate flags real model changes, not
runner noise.

Emits `BENCH_kernels.json` (shared envelope: `name` / `config` /
`results` / `primary_metric`). The primary metric is the analytic HBM
bytes-touched reduction of the fused select+pack over the XLA chain.

Run: PYTHONPATH=src python benchmarks/kernel_microbench.py
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import dpmr
from repro.configs.base import DPMRConfig
from repro.optim import compression

# paper mesh (2 pods x 256 chips), per-STEP SGD regime: the kernels serve
# train_step's sparsified reduce — the full-batch GD accumulate path falls
# back to the exact shuffle and never ranks (strategies.TopKReduceStrategy)
P, PODS = 512, 2
K = 64                    # features per sample
BATCH_LOCAL = 4096        # per-device SGD minibatch
TOPK_FRAC = 0.25
F32 = I32 = 4             # bytes per element, both buffers

_CFG = DPMRConfig(max_features_per_sample=K, topk_frac=TOPK_FRAC)
CAP = dpmr.capacity_for_shards(_CFG, BATCH_LOCAL, P)
TOPK = compression.topk_count(CAP, TOPK_FRAC)


def select_pack_ledger(p: int = P, cap: int = CAP, k: int = TOPK) -> dict:
    """Per-op HBM bytes of the XLA chain vs the fused kernel.

    The chain is `kernels/ref.py:select_pack_ref` op by op; every op reads
    its operands and writes its result through HBM (none of the
    intermediates fit in registers at (P, cap) scale, and the gathers /
    top_k / scatter break XLA fusion). The fused kernel reads the three
    (P, cap) inputs and writes the three outputs exactly once — every
    intermediate (the comparison mask included) lives in VMEM.
    """
    e, ek = p * cap, p * k
    chain = [
        # (op, bytes read, bytes written)
        ("compensate", 3 * e * F32, e * F32),        # send+carry, mask ids
        ("abs_key", 2 * e * F32, e * F32),           # comp, ids -> key
        ("top_k", e * F32, 2 * ek * F32),            # key -> (vals, idx)
        ("mask_scatter", ek * I32, (e + ek) * F32),  # topk_select's mask
        ("gather_ids", 2 * ek * I32, ek * I32),      # idx + touched ids
        ("gather_vals", 3 * ek * F32, ek * F32),     # idx, comp, ids_k mask
        ("residual", 3 * e * F32, e * F32),          # mask, ids, comp
    ]
    fused = [
        ("select_pack", 3 * e * F32, (e + 2 * ek) * F32),
    ]
    tot = lambda ops: sum(r + w for _, r, w in ops)  # noqa: E731
    return {
        "chain_ops": [{"op": o, "read": r, "write": w} for o, r, w in chain],
        "chain_bytes": tot(chain),
        "fused_bytes": tot(fused),
        "hbm_reduction_x": tot(chain) / tot(fused),
    }


def owner_accumulate_ledger(seed: int = 0) -> dict:
    """Owner-side memory adds: per received slot vs per unique feature.

    A seeded draw of one destination's received ids at the bench geometry
    (every sample contributes K hashed features; ~BATCH_LOCAL*K/P of them
    land on each owner). The XLA path scatter-adds every live slot into
    the (block,) accumulator — serialized read-modify-writes on TPU. The
    kernel path sorts and emits one run total per unique feature; the sort
    is a bandwidth-friendly bitonic pass counted here as its own ledger
    line, not hidden.
    """
    rng = np.random.default_rng(seed)
    n_recv = BATCH_LOCAL * K // P            # slots landing on one owner
    block = (_CFG.num_features // P)
    ids = rng.integers(0, block, size=n_recv).astype(np.int32)
    unique = int(np.unique(ids).size)
    # scatter-add: read + write the accumulator per slot, read id + grad
    scatter_bytes = n_recv * (2 * F32 + I32 + F32)
    # kernel: sort touches (id, grad) ~log2 passes, then one RMW per run
    sort_passes = int(np.ceil(np.log2(max(n_recv, 2))))
    kernel_bytes = (sort_passes * n_recv * (I32 + F32)
                    + unique * (2 * F32 + I32 + F32))
    return {
        "received_slots": n_recv,
        "unique_features": unique,
        "owner_adds_reduction_x": n_recv / unique,
        "scatter_bytes": scatter_bytes,
        "kernel_bytes_incl_sort": kernel_bytes,
    }


def parity_smoke(seed: int = 0) -> dict:
    """Interpret-mode bit-parity of both kernels vs kernels/ref.py on a
    small seeded case (the full sweep lives in tests/test_kernels.py)."""
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    p, cap, k = 4, 64, 16
    ids = rng.integers(-1, 256, size=(p, cap)).astype(np.int32)
    send = np.where(ids >= 0, rng.normal(size=(p, cap)), 0.0).astype(
        np.float32)
    carry = np.where(ids >= 0, rng.normal(size=(p, cap)), 0.0).astype(
        np.float32)
    got = ops.select_pack(jnp.asarray(send), jnp.asarray(ids),
                          jnp.asarray(carry), k=k, impl="pallas_interpret")
    want = ref.select_pack_ref(jnp.asarray(send), jnp.asarray(ids),
                               jnp.asarray(carry), k=k)
    sp_exact = all(np.array_equal(np.asarray(g), np.asarray(w))
                   for g, w in zip(got, want))

    # integer-valued grads: every per-feature sum is exactly representable,
    # so the kernel's reassociated run totals must match the scatter bits
    g_int = rng.integers(-8, 9, size=(p, cap)).astype(np.float32)
    acc = np.zeros((256,), np.float32)
    oa = {}
    for impl in ("xla", "pallas_interpret"):
        oa[impl] = np.asarray(ops.owner_accumulate(
            jnp.asarray(ids), jnp.asarray(g_int), jnp.asarray(acc), 0,
            impl=impl))
    oa_exact = np.array_equal(oa["xla"], oa["pallas_interpret"])
    return {"select_pack_bit_exact": bool(sp_exact),
            "owner_accumulate_bit_exact": bool(oa_exact)}


def run(write_json: bool = True) -> dict:
    sp = select_pack_ledger()
    oa = owner_accumulate_ledger()
    parity = parity_smoke()
    if not all(parity.values()):
        raise AssertionError(f"interpret-mode parity failed: {parity}")
    out = {
        "name": "kernels",
        "config": {"shards": P, "pods": PODS, "batch_local": BATCH_LOCAL,
                   "features_per_sample": K, "capacity": CAP,
                   "topk_frac": TOPK_FRAC, "k": TOPK},
        # consumed by scripts/check_bench.py --compare (nightly CI gate):
        # analytic, so a >20% drop means the kernel's memory model changed
        "primary_metric": {"path": "results.select_pack_hbm_reduction_x",
                           "higher_is_better": True},
        "results": {
            "select_pack": sp,
            "select_pack_hbm_reduction_x": sp["hbm_reduction_x"],
            "owner_accumulate": oa,
            "parity": parity,
        },
    }
    if write_json:
        with open("BENCH_kernels.json", "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    out = run()
    sp = out["results"]["select_pack"]
    oa = out["results"]["owner_accumulate"]
    print(f"geometry: P={P} cap={CAP} k={TOPK} (frac={TOPK_FRAC})")
    print(f"{'op':>14s} {'read B':>12s} {'write B':>12s}")
    for r in sp["chain_ops"]:
        print(f"{r['op']:>14s} {r['read']:>12.3e} {r['write']:>12.3e}")
    print(f"XLA chain {sp['chain_bytes']:.3e} B  ->  fused "
          f"{sp['fused_bytes']:.3e} B  (x{sp['hbm_reduction_x']:.2f} less "
          "HBM traffic)")
    print(f"owner adds: {oa['received_slots']} slots -> "
          f"{oa['unique_features']} unique features "
          f"(x{oa['owner_adds_reduction_x']:.2f} fewer RMWs)")
    print(f"interpret-mode parity: {out['results']['parity']}")
    print("wrote BENCH_kernels.json")
    return out


if __name__ == "__main__":
    main()
