"""The paper's central efficiency claim, quantified: distributing parameters
BY SHUFFLE (a2a of requested rows) vs SHIPPING THE TABLE (all-gather), plus
the psum_scatter hybrid, using each registered strategy's own wire model.

Per device per step (forward + reduce collectives both counted; the seed
version of this table counted only allgather's forward table movement, so
its ag/a2a ratios were ~2x smaller):
  a2a:          3 * P * cap * 4 bytes        (independent of |F|!)
  allgather:    ~ 2 * |F| * 4 bytes          (grows with the feature space)
  psum_scatter: 2 * P * cap * 4 + |F| * 4    (sparse fwd, dense reduce)

This is exactly why DPMR scales to the paper's 50B-feature regime where a
parameter-server-free broadcast cannot. All strategies are implemented in
repro/api/strategies.py and verified to produce identical parameters
(tests/test_dpmr.py::test_strategies_agree); here we sweep |F| and query
each strategy's `bytes_per_device` cost model — the same buffer math the
engine executes ((P, cap) f32 a2a buffers; the (F,) table).
"""
from __future__ import annotations

from repro.api import get_strategy, list_strategies
from repro.api.strategies import StrategyContext
from repro.configs.base import DPMRConfig
from repro.core import dpmr


def run(p: int = 256, batch: int = 1 << 16, k: int = 64,
        strategies=("a2a", "allgather", "psum_scatter")):
    rows = []
    for logf in (20, 24, 27, 30, 33):
        f = 1 << logf
        cfg = DPMRConfig(num_features=f, max_features_per_sample=k)
        cap = dpmr.capacity_for_shards(cfg, batch // p, p)
        ctx = StrategyContext(axes=(), num_shards=p,
                              block_size=-(-f // p), capacity=cap)
        row = {"features": f}
        for name in strategies:
            row[name] = get_strategy(name).bytes_per_device(ctx)
        if "a2a" in row and "allgather" in row:
            row["ratio"] = row["allgather"] / row["a2a"]
        rows.append(row)
    return rows


def main():
    names = ("a2a", "allgather", "psum_scatter")
    rows = run(strategies=names)
    hdr = f"{'|F|':>12s}" + "".join(f" {n + ' B/dev':>18s}" for n in names)
    print(hdr + f" {'ag/a2a':>9s}")
    for r in rows:
        line = f"{r['features']:>12.3e}"
        line += "".join(f" {r[n]:>18.3e}" for n in names)
        print(line + f" {r.get('ratio', float('nan')):>9.1f}")
    return rows


if __name__ == "__main__":
    main()
