"""The paper's central efficiency claim, quantified: distributing parameters
BY SHUFFLE (a2a of requested rows) vs SHIPPING THE TABLE (all-gather).

Per device per step:
  a2a:        3 * P * cap * 4 bytes          (independent of |F|!)
  all-gather: |F| * 4 * (P-1)/P bytes        (grows with the feature space)

This is exactly why DPMR scales to the paper's 50B-feature regime where a
parameter-server-free broadcast cannot. Both strategies are implemented in
core/dpmr.py and verified to produce identical parameters
(tests/test_dpmr.py::test_a2a_equals_allgather); here we sweep |F|.

Wire-byte model cross-checked against the engine's own buffers (the a2a
buffers ARE (P, cap) f32; the all-gather IS the (F,) table).
"""
from __future__ import annotations

from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.launch.mesh import make_host_mesh


def run(p: int = 256, batch: int = 1 << 16, k: int = 64):
    rows = []
    for logf in (20, 24, 27, 30, 33):
        f = 1 << logf
        cfg = DPMRConfig(num_features=f, max_features_per_sample=k)
        b_loc = batch // p
        n = b_loc * k
        mean = max(1, n // p)
        cap = min(n, max(16, -(-int(4.0 * mean) // 8) * 8))
        a2a = 3 * p * cap * 4
        ag = (f // p) * 4 * (p - 1)      # per-device receive of the table
        rows.append({"features": f, "a2a_bytes_per_dev": a2a,
                     "allgather_bytes_per_dev": ag,
                     "ratio": ag / a2a})
    return rows


def main():
    rows = run()
    print(f"{'|F|':>12s} {'a2a B/dev':>12s} {'allgather B/dev':>16s} "
          f"{'ag/a2a':>9s}")
    for r in rows:
        print(f"{r['features']:>12.3e} {r['a2a_bytes_per_dev']:>12.3e} "
              f"{r['allgather_bytes_per_dev']:>16.3e} {r['ratio']:>9.1f}")
    return rows


if __name__ == "__main__":
    main()
