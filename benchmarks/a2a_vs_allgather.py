"""The paper's central efficiency claim, quantified: distributing parameters
BY SHUFFLE (a2a of requested rows) vs SHIPPING THE TABLE (all-gather), plus
the psum_scatter / hier_a2a / compressed_reduce variants, using each
registered strategy's own two-tier wire model.

Per device per step (forward + reduce collectives both counted; the seed
version of this table counted only allgather's forward table movement, so
its ag/a2a ratios were ~2x smaller):
  a2a:               3 * (P-1) * cap * 4 bytes (independent of |F|!)
  allgather:         ~ 2 * |F| * 4 bytes       (grows with the feature space)
  psum_scatter:      2 * (P-1) * cap * 4 + |F| * 4 (sparse fwd, dense reduce)
  hier_a2a:          shuffle on ICI; DCN only carries 2 * (|F|/P) * (Po-1)
                     * 4 (pod mirror + per-pod partials)
  compressed_reduce: sparse fwd + the dense reduce at int8 (~4x fewer
                     reduce bytes than psum_scatter)

This is exactly why DPMR scales to the paper's 50B-feature regime where a
parameter-server-free broadcast cannot. All strategies are implemented in
repro/api/strategies.py and the exact ones verified to produce identical
parameters (tests/test_dpmr.py::test_strategies_agree); here we sweep |F|
and query each strategy's `bytes_per_device` cost model — the same buffer
math the engine executes ((P, cap) f32 a2a buffers; the (F,) table).
`run(pods=2)` splits every figure into its ICI (inner) and DCN (outer)
tiers; benchmarks/strategy_hierarchy.py records that split as a JSON
artifact.
"""
from __future__ import annotations

from repro.api import get_strategy
from repro.api.strategies import StrategyContext
from repro.configs.base import DPMRConfig
from repro.core import dpmr

# every registered strategy, dynamically — a newly registered one shows up
# in the table without this benchmark having to know it (topk_reduce /
# overlap_a2a arrived this way)
def _strategies():
    from repro.api import list_strategies

    return tuple(list_strategies())


def run(p: int = 256, batch: int = 1 << 16, k: int = 64,
        strategies=None, pods: int = 1):
    strategies = _strategies() if strategies is None else strategies
    rows = []
    for logf in (20, 24, 27, 30, 33):
        f = 1 << logf
        cfg = DPMRConfig(num_features=f, max_features_per_sample=k)
        cap = dpmr.capacity_for_shards(cfg, batch // p, p)
        ctx = StrategyContext(axes=(), num_shards=p,
                              block_size=-(-f // p), capacity=cap,
                              outer_shards=pods)
        row = {"features": f}
        for name in strategies:
            wb = get_strategy(name).bytes_per_device(ctx)
            row[name] = wb.total
            row[name + "_tiers"] = {"inner": wb.inner, "outer": wb.outer}
        if "a2a" in row and "allgather" in row:
            row["ratio"] = row["allgather"] / row["a2a"]
        rows.append(row)
    return rows


def _print_table(rows, names, tier=None):
    col = (lambda r, n: r[n + "_tiers"][tier]) if tier else \
        (lambda r, n: r[n])
    hdr = f"{'|F|':>12s}" + "".join(f" {n + ' B/dev':>22s}" for n in names)
    print(hdr + (f" {'ag/a2a':>9s}" if tier is None else ""))
    for r in rows:
        line = f"{r['features']:>12.3e}"
        line += "".join(f" {col(r, n):>22.3e}" for n in names)
        if tier is None:
            line += f" {r.get('ratio', float('nan')):>9.1f}"
        print(line)


def main():
    rows = run()
    print("== single-tier mesh (P=256, all ICI): total bytes/device ==")
    _print_table(rows, _strategies())
    rows2 = run(p=512, batch=1 << 24, pods=2)
    print("\n== two-pod mesh (P=512, Po=2, full-batch regime): DCN tier ==")
    _print_table(rows2, _strategies(), tier="outer")
    return rows + rows2


if __name__ == "__main__":
    main()
