"""Serving benchmark: coalescing latency/QPS, hot-cache hit rate, bucketing.

Measures the serving tentpole end to end on the host mesh and emits
`BENCH_serving.json` with the shared envelope (`name` / `config` /
`results`):

  parity        every coalesced, bucket-padded, cache-accelerated answer is
                bit-identical to per-request `engine.predict` (asserted,
                recorded as a boolean — the correctness floor under the
                performance numbers)
  hot_cache     DETERMINISTIC hit rate of the Zipf-head parameter cache on
                a seeded trace processed sequentially (no threads, no
                clocks): purely a function of the trace + cache config,
                so it is the `primary_metric` the nightly regression gate
                compares (latency/QPS are machine noise; hit rate is not)
  latency_qps   p50/p99 request latency and sustained QPS over a
                `max_wait_ms` x hot-cache on/off sweep with concurrent
                clients — the knob-tradeoff table for docs/SERVING.md
  bucketing     compiled `StepFns` entries with raw per-size `predict`
                vs `predict_padded`'s power-of-two ladder on mixed request
                sizes (the recompile-trap fix, counted not timed)

    PYTHONPATH=src python benchmarks/serving.py
    PYTHONPATH=src python benchmarks/serving.py --requests 64 --out /tmp
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.serve import (BatchingConfig, DPMRServeEngine, HotCacheConfig,
                         HotFeatureCache, ServeMetrics)

F = 1 << 12
K = 8


def _engine(mesh, steps: int = 8) -> DPMREngine:
    cfg = DPMRConfig(num_features=F, max_features_per_sample=K, max_hot=16)
    eng = DPMREngine(cfg, mesh)
    src = get_source("zipf_sparse", batch_size=16, num_batches=8,
                     num_features=F, features_per_sample=K, seed=7)
    eng.fit_sgd(src.iter_batches(), steps=steps)
    return eng


def _trace(n: int, request_size: int, seed: int):
    src = get_source("zipf_sparse", batch_size=request_size, num_batches=n,
                     num_features=F, features_per_sample=K, seed=seed)
    return [src.batch(i) for i in range(n)]


def bench_hot_cache(eng: DPMREngine, requests: int, request_size: int,
                    hot_cfg: HotCacheConfig, seed: int = 0) -> dict:
    """Sequential deterministic trace: observe + lookup each request once,
    falling back to the sparse path on a miss (as the serve engine does).
    Every hit is asserted bit-identical to `engine.predict`. Single-sample
    requests by default: a hit needs EVERY feature of the request in the
    mirror, so the hit rate reads as 'fraction of samples drawn entirely
    from the cached Zipf head' — the paper's hot/cold premise, measured."""
    cache = HotFeatureCache(eng, hot_cfg, ServeMetrics())
    for req in _trace(requests, request_size, seed):
        cache.observe(req["ids"])
        got = cache.lookup(req["ids"], req["vals"])
        ref = eng.predict(req)
        if got is not None:
            assert np.array_equal(got, ref), "cache hit must be bit-exact"
    m = cache.metrics.snapshot()
    hits = m.get("cache_hits", 0)
    misses = m.get("cache_misses", 0)
    return {
        "trace_requests": requests,
        "request_size": request_size,
        "max_hot": hot_cfg.max_hot,
        "window": hot_cfg.window,
        "hits": hits,
        "misses": misses,
        "hit_rate": hits / max(hits + misses, 1),
        "refreshes": m.get("cache_refreshes", 0),
    }


def bench_latency_qps(eng: DPMREngine, requests: int, request_size: int,
                      clients: int, wait_ms_sweep, seed: int = 1) -> list:
    rows = []
    trace = _trace(requests, request_size, seed)
    refs = [eng.predict(req) for req in trace]
    for wait_ms in wait_ms_sweep:
        for use_hot in (False, True):
            hot = HotCacheConfig(max_hot=512, threshold=0.0, window=256,
                                 refresh_every=4) if use_hot else None
            srv = DPMRServeEngine(
                eng, batching=BatchingConfig(max_batch=64,
                                             max_wait_ms=wait_ms),
                hot_cache=hot)
            results: list = [None] * requests
            srv.metrics.reset_clock()
            t0 = time.perf_counter()

            def client(lo, hi, results=results, srv=srv):
                for i in range(lo, hi):
                    results[i] = srv.submit(trace[i]["ids"],
                                            trace[i]["vals"])

            per = -(-requests // clients)
            threads = [threading.Thread(
                target=client, args=(c * per, min(requests, (c + 1) * per)))
                for c in range(clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            probs = [np.asarray(f.result(timeout=300)) for f in results]
            wall = time.perf_counter() - t0
            srv.stop()
            for got, ref in zip(probs, refs, strict=True):
                assert np.array_equal(got, ref), \
                    "coalesced serving must stay bit-exact"
            m = srv.metrics_snapshot()
            rows.append({
                "max_wait_ms": wait_ms,
                "hot_cache": use_hot,
                "requests": requests,
                "clients": clients,
                "wall_s": round(wall, 4),
                "qps": round(requests / max(wall, 1e-9), 1),
                "latency_p50_ms": round(m.get("latency_p50_ms", 0.0), 3),
                "latency_p99_ms": round(m.get("latency_p99_ms", 0.0), 3),
                "flushes": m.get("flushes", 0),
                "batch_mean": round(m.get("batch_mean", 0.0), 2),
                "padding_frac": round(m.get("padding_frac", 0.0), 4),
                "hot_hit_rate": round(m.get("hot_hit_rate", 0.0), 4),
            })
    return rows


def bench_bucketing(mesh, sizes=(1, 2, 3, 4, 5, 6, 7, 8)) -> dict:
    """Distinct compiled StepFns entries after serving mixed request sizes:
    one per size without bucketing, one per ladder rung with. Counted on
    fresh engines so the numbers are exact, not timed."""
    src = get_source("zipf_sparse", batch_size=max(sizes), num_batches=1,
                     num_features=F, features_per_sample=K, seed=2)
    b = src.batch(0)

    raw = _engine(mesh, steps=0)
    trained = len(raw._fns)          # fit_sgd's own entry (none at steps=0)
    for n in sizes:
        raw.predict({"ids": b["ids"][:n], "vals": b["vals"][:n]})
    unbucketed = len(raw._fns) - trained

    padded = _engine(mesh, steps=0)
    trained = len(padded._fns)
    for n in sizes:
        padded.predict_padded({"ids": b["ids"][:n], "vals": b["vals"][:n]})
    bucketed = len(padded._fns) - trained

    assert bucketed < unbucketed, (bucketed, unbucketed)
    return {
        "request_sizes": list(sizes),
        "unbucketed_step_fns": unbucketed,
        "bucketed_step_fns": bucketed,
        "compile_reduction_x": round(unbucketed / bucketed, 3),
    }


def run(requests: int = 96, request_size: int = 4, clients: int = 8,
        wait_ms_sweep=(0.5, 2.0, 8.0), write_json: bool = True,
        out_dir: str = ".") -> dict:
    mesh = make_host_mesh(1, 1)
    eng = _engine(mesh)
    # refresh_every=4: the mirror tracks the sliding window closely enough
    # that the hit rate measures Zipf-head coverage, not refresh droop
    hot_cfg = HotCacheConfig(max_hot=512, threshold=0.0, window=256,
                             refresh_every=4)
    results = {
        "hot_cache": bench_hot_cache(eng, requests, 1, hot_cfg),
        "latency_qps": bench_latency_qps(eng, requests, request_size,
                                         clients, wait_ms_sweep),
        "bucketing": bench_bucketing(mesh),
    }
    # parity is asserted inside both serving sections above; surface it as
    # a recorded fact so the JSON states the correctness floor explicitly
    results["parity"] = {
        "bit_exact_vs_predict": True,
        "requests_checked": requests * (1 + 2 * len(wait_ms_sweep)),
    }
    out = {
        "name": "serving",
        "config": {"num_features": F, "max_features_per_sample": K,
                   "requests": requests, "request_size": request_size,
                   "clients": clients, "wait_ms_sweep": list(wait_ms_sweep),
                   "hot": {"max_hot": hot_cfg.max_hot,
                           "window": hot_cfg.window,
                           "threshold": hot_cfg.threshold,
                           "refresh_every": hot_cfg.refresh_every},
                   "hot_trace_request_size": 1},
        # deterministic: a seeded trace processed sequentially — safe to
        # regression-gate at 20% where latency/QPS would flag runner noise
        "primary_metric": {"path": "results.hot_cache.hit_rate",
                           "higher_is_better": True},
        "results": results,
    }
    if write_json:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, "BENCH_serving.json")
        with open(path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--request-size", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--wait-ms", type=float, nargs="+",
                    default=[0.5, 2.0, 8.0])
    ap.add_argument("--out", default=".", help="BENCH_serving.json dir")
    args = ap.parse_args()
    out = run(requests=args.requests, request_size=args.request_size,
              clients=args.clients, wait_ms_sweep=tuple(args.wait_ms),
              out_dir=args.out)
    hc = out["results"]["hot_cache"]
    print(f"hot cache: hit rate {hc['hit_rate']:.3f} "
          f"({hc['hits']}/{hc['hits'] + hc['misses']}), "
          f"{hc['refreshes']} refreshes")
    bk = out["results"]["bucketing"]
    print(f"bucketing: {bk['unbucketed_step_fns']} -> "
          f"{bk['bucketed_step_fns']} compiled step fns "
          f"({bk['compile_reduction_x']}x)")
    print(f"{'wait_ms':>8s} {'hot':>5s} {'p50_ms':>8s} {'p99_ms':>8s} "
          f"{'qps':>8s} {'flushes':>8s} {'batch':>6s} {'hit%':>6s}")
    for r in out["results"]["latency_qps"]:
        print(f"{r['max_wait_ms']:>8.1f} {str(r['hot_cache']):>5s} "
              f"{r['latency_p50_ms']:>8.2f} {r['latency_p99_ms']:>8.2f} "
              f"{r['qps']:>8.1f} {r['flushes']:>8d} {r['batch_mean']:>6.1f} "
              f"{r['hot_hit_rate']:>6.3f}")
    print(f"wrote {os.path.join(args.out, 'BENCH_serving.json')}")
    return out


if __name__ == "__main__":
    main()
