"""Roofline analysis from the dry-run artifacts (deliverable g).

Inputs:
  results/dryrun/<arch>__<shape>__single.json   memory_analysis + raw HLO
                                                collective aggregates (scan
                                                bodies counted once — used
                                                for memory only)
  results/probes/<arch>__<shape>__probe.json    1/2-unit UNROLLED cost probes
                                                (exact affine extrapolation)

Terms per (arch x shape) on the 256-chip v5e pod:
  compute    = FLOPs_step        / (chips * 197e12)
  memory     = HBM bytes_step    / (chips * 819e9)
  collective = sum over ops of op_bytes * alg_factor / (chips-normalized
               50e9 per link; ring terms use (P-1)/P of the participating
               group)

Extrapolation: cost(n units) is affine, so step = micro x
[c1 + (units-1)(c2-c1)]. FLOPs/bytes from cost_analysis are PER DEVICE
(the compiled module is the partitioned per-device program).

Known deviations (documented in EXPERIMENTS.md):
  - xlstm sLSTM keeps a true lax.scan over time: its probe FLOPs get an
    analytic correction (+ (S-1) x per-token cell cost).
  - all-reduce counts occasionally decrease from probe1 to probe2 (XLA
    restructuring); negative slopes are clamped to 0.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.configs import SHAPES, get_config
from repro.configs.base import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CHIPS = 256
# Per-device WIRE bytes per result byte, ring algorithms, group size g.
# The HLO shapes are from the PARTITIONED per-device module, so:
#   all-gather result   = full gathered tensor  -> wire = (g-1)/g x result
#   reduce-scatter res. = the local shard       -> wire = (g-1)   x result
#   all-reduce result   = full tensor           -> wire = 2(g-1)/g x result
#   all-to-all result   = local buffer          -> wire = (g-1)/g x result
#   collective-permute  = one neighbor transfer -> wire = 1       x result
ALG_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1.0),
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}
# mesh axes are 16x16: a collective over one axis spans 16 devices; without
# per-op group parsing in the probe aggregates we use the conservative g=16
DEFAULT_GROUP = 16


def _slstm_correction(arch: str, shape_name: str, kind: str) -> float:
    """Analytic FLOPs for the sLSTM time-scan the probe counts once."""
    if arch != "xlstm-125m" or kind == "decode":
        return 0.0
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    per_tok = 2 * d * 4 * d + 2 * h * dh * 4 * dh   # W + R matmuls
    n_slstm = cfg.num_layers // max(cfg.slstm_every, 1)
    toks = shape.global_batch * shape.seq_len
    fwd = n_slstm * per_tok * toks
    mult = 4.0 if kind == "train" else 1.0          # fwd+remat-fwd+2x bwd
    return fwd * mult / CHIPS                        # per device


def extrapolate(probe: dict) -> dict:
    """probe json -> per-device per-STEP costs."""
    p1, p2 = probe["probe1"], probe["probe2"]
    units = probe["units"]
    micro = probe.get("microbatches", 1)

    def aff(a, b):
        return max(a + (units - 1) * max(b - a, 0.0), a)

    flops = aff(p1["flops"], p2["flops"]) * micro
    flops += _slstm_correction(probe["arch"], probe["shape"],
                               probe["kind"])
    hbm_hlo = aff(p1["bytes_accessed"], p2["bytes_accessed"]) * micro
    hbm = analytic_hbm_bytes(probe["arch"], probe["shape"], probe["kind"],
                             micro)
    colls = {}
    ops = set(p1["collective_summary"]) | set(p2["collective_summary"])
    for op in ops:
        b1 = p1["collective_summary"].get(op, {}).get("bytes", 0)
        b2 = p2["collective_summary"].get(op, {}).get("bytes", 0)
        colls[op] = aff(float(b1), float(b2)) * micro
    return {"flops": flops, "hbm_bytes": hbm, "hbm_bytes_hlo": hbm_hlo,
            "collective_bytes": colls}


def roofline_terms(step: dict) -> dict:
    comp = step["flops"] / PEAK_FLOPS_BF16          # flops already per-device
    mem = step["hbm_bytes"] / HBM_BW
    coll = 0.0
    for op, bytes_ in step["collective_bytes"].items():
        factor = ALG_FACTOR.get(op, lambda g: 1.0)(DEFAULT_GROUP)
        coll += bytes_ * factor / ICI_BW
    return {"compute_s": comp, "memory_s": mem, "collective_s": coll,
            "dominant": max(
                [("compute", comp), ("memory", mem), ("collective", coll)],
                key=lambda kv: kv[1])[0]}


def analytic_hbm_bytes(arch: str, shape_name: str, kind: str,
                       micro: int) -> float:
    """Per-device HBM traffic model (documented; the HLO 'bytes accessed' is
    an unfused upper bound that over-counts 10-100x on TPU, where broadcasts
    and elementwise chains fuse into the matmuls).

    train:   passes = 3 x micro (fwd + remat-fwd + bwd); per pass each
             device reads its model-parallel slice of every weight (the
             data-axis gather writes + reads the gathered copy: x2) and
             streams ~C_ACT residual-sized activation tensors per layer.
    prefill: 1 pass, same structure.
    decode:  reads the model slice of all (active) weights + the KV/state
             cache once per token.
    """
    C_ACT = 8.0
    MODEL_WAYS = 16.0          # model-axis degree of the 16x16 pod
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    act_bytes = 2.0            # bf16 activations
    n_active = cfg.active_param_count()
    w_slice = 2.0 * n_active / MODEL_WAYS       # bf16 weights, model slice
    layers = cfg.num_layers + cfg.encoder_layers

    if kind in ("train", "prefill"):
        passes = (3 * micro) if kind == "train" else 1
        toks_loc = shape.global_batch * shape.seq_len / CHIPS
        act = passes * layers * toks_loc * cfg.d_model * act_bytes * C_ACT
        weights = passes * 2.0 * w_slice
        return act + weights
    # decode
    toks_loc = shape.global_batch / CHIPS * MODEL_WAYS  # model ways share B
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.sliding_window:
        slots = min(shape.seq_len, cfg.sliding_window)
    else:
        slots = shape.seq_len
    if cfg.family == "ssm":
        cache = layers * (2 * cfg.d_model) ** 2 / cfg.num_heads * 4.0
        cache *= shape.global_batch / CHIPS
    elif cfg.family == "hybrid":
        every = max(cfg.attn_every, 1)
        cache = (cfg.num_layers // every) * slots * kh * hd * 2 * 2
        cache *= shape.global_batch / CHIPS
    else:
        cache = layers * slots * kh * hd * 2 * 2
        cache *= shape.global_batch / CHIPS
    weights = 2.0 * n_active / CHIPS   # each device reads its weight shard
    return weights + cache


def model_flops(arch: str, shape_name: str, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), global per step."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    toks = shape.global_batch          # one token per sequence
    return 2.0 * n * toks


def _analytic_row(arch: str, shape_name: str) -> dict:
    """Fallback for cells whose unrolled probe exceeds the compile budget
    (SSM prefill_32k: 256 unrolled SSD chunks). FLOPs from the chunked-SSD /
    mLSTM closed forms; collectives from the per-pass param-gather model.
    Clearly marked method=analytic."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    toks = shape.global_batch * shape.seq_len
    n = cfg.active_param_count()
    fwd = 2.0 * n * toks
    # chunked linear-attention seq term: ~4*Lc*(Dk+Dv) per token per layer
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        h = di // 64
        seq = 4.0 * 128 * (cfg.ssm_state + 64) * h * toks * cfg.num_layers
        # shared attention every attn_every layers, full causal
        n_att = cfg.num_layers // max(cfg.attn_every, 1)
        seq += 2.0 * shape.seq_len * cfg.d_model * toks * n_att
    else:  # xlstm
        di = 2 * cfg.d_model
        dh = di // cfg.num_heads
        seq = 4.0 * 128 * (2 * dh) * cfg.num_heads * toks * \
            (cfg.num_layers // 2)
        seq += 2.0 * 4 * cfg.d_model * cfg.d_model * toks * \
            (cfg.num_layers // 2)   # sLSTM W+R per token
    flops = (fwd + seq) / CHIPS
    hbm = analytic_hbm_bytes(arch, shape_name, "prefill", 1)
    colls = {"all-gather": 2.0 * n / 16.0}        # weight gathers, one pass
    terms = roofline_terms({"flops": flops, "hbm_bytes": hbm,
                            "collective_bytes": colls})
    mf = model_flops(arch, shape_name, "prefill")
    ideal = mf / CHIPS / PEAK_FLOPS_BF16
    dom = max(terms.values() if False else
              [terms["compute_s"], terms["memory_s"],
               terms["collective_s"]])
    return {"arch": arch, "shape": shape_name, "kind": "prefill", **terms,
            "model_flops": mf, "hlo_flops_global": flops * CHIPS,
            "useful_ratio": mf / (flops * CHIPS),
            "roofline_fraction": ideal / dom if dom else 0.0,
            "hbm_bytes_per_dev": hbm, "hbm_bytes_hlo_upper": None,
            "memory_s_hlo_upper": None, "collective_bytes": colls,
            "temp_bytes_per_dev": None, "arg_bytes_per_dev": None,
            "method": "analytic"}


def analyze(dryrun_dir: str = "results/dryrun",
            probe_dir: str = "results/probes",
            out_path: str | None = "results/roofline.json"):
    paths = sorted(glob.glob(os.path.join(probe_dir, "*__probe.json")))
    if not paths:
        print(
            f"roofline: no probe artifacts under {probe_dir!r} — nothing to "
            "analyze.\nGenerate them first:\n"
            "  python -m repro.launch.dryrun --all --out results/dryrun\n"
            "  python -m repro.launch.dryrun --cell <arch>:<shape> --probe "
            "--out results/probes\n"
            "or run the strategy-wire mode, which needs no artifacts:\n"
            "  python benchmarks/roofline.py --dpmr",
            file=sys.stderr)
        return []
    rows = []
    for path in paths:
        probe = json.load(open(path))
        if probe.get("status") == "analytic":
            rows.append(_analytic_row(probe["arch"], probe["shape"]))
            continue
        if probe.get("status") != "ok":
            continue
        arch, shape = probe["arch"], probe["shape"]
        step = extrapolate(probe)
        terms = roofline_terms(step)
        mf = model_flops(arch, shape, probe["kind"])
        hlo_global = step["flops"] * CHIPS
        mem_path = os.path.join(dryrun_dir, f"{arch}__{shape}__single.json")
        memory = {}
        if os.path.exists(mem_path):
            mem_rec = json.load(open(mem_path))
            memory = mem_rec.get("memory_analysis", {})
        dom_s = max(terms["compute_s"], terms["memory_s"],
                    terms["collective_s"])
        # roofline fraction: the time an IDEAL machine needs for the USEFUL
        # model flops, over the best achievable time for OUR compiled step
        # (max of the three terms, i.e. perfect overlap). 1.0 = the step is
        # pure useful compute at peak; <1 = waste flops and/or another
        # resource dominates (e.g. decode is memory-bound by nature).
        ideal_s = mf / CHIPS / PEAK_FLOPS_BF16
        rows.append({
            "arch": arch, "shape": shape, "kind": probe["kind"],
            **terms,
            "model_flops": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "roofline_fraction": ideal_s / dom_s if dom_s else 0.0,
            "hbm_bytes_per_dev": step["hbm_bytes"],
            "hbm_bytes_hlo_upper": step["hbm_bytes_hlo"],
            "memory_s_hlo_upper": step["hbm_bytes_hlo"] / HBM_BW,
            "collective_bytes": step["collective_bytes"],
            "temp_bytes_per_dev": memory.get("temp_size_in_bytes"),
            "arg_bytes_per_dev": memory.get("argument_size_in_bytes"),
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def print_table(rows):
    hdr = (f"{'arch':<22s} {'shape':<12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch']:<22s} {r['shape']:<12s} "
              f"{r['compute_s']:>10.4f} {r['memory_s']:>10.4f} "
              f"{r['collective_s']:>10.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:>7.2f} "
              f"{100*r['roofline_fraction']:>6.1f}%")


def dpmr_rows(bandwidth=None):
    """DPMR-strategy roofline mode: price the sparse step's wire per
    strategy per geometry from the SAME audited `WireBytes` declarations
    the strategy contract auditor checks against traced jaxprs (rule
    W-MATCH in `repro.analysis`), at the autotuner's per-tier planning
    bandwidths (`repro.api.autotune.WireBandwidth`: ICI ~10x DCN). No
    dry-run artifacts needed — this mode is purely analytic, the sparse
    face's counterpart to the dense probe extrapolation above."""
    from repro.analysis import build_contexts
    from repro.api import autotune
    from repro.api.strategies import get_strategy, list_strategies

    bw = bandwidth or autotune.WireBandwidth()
    rows = []
    for actx in build_contexts():
        for name in list_strategies():
            wire = get_strategy(name).bytes_per_device(actx.ctx)
            rows.append({
                "geometry": actx.name, "strategy": name,
                "inner_bytes": int(wire.inner), "outer_bytes": int(wire.outer),
                "wire_s": autotune.wire_cost(wire, bw),
            })
    return rows


def print_dpmr_table(rows):
    hdr = (f"{'geometry':<12s} {'strategy':<22s} {'inner_B':>12s} "
           f"{'outer_B':>12s} {'wire_us':>10s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["geometry"], r["wire_s"])):
        print(f"{r['geometry']:<12s} {r['strategy']:<22s} "
              f"{r['inner_bytes']:>12d} {r['outer_bytes']:>12d} "
              f"{1e6 * r['wire_s']:>10.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dpmr", action="store_true",
                    help="price the DPMR sparse step from the audited "
                         "per-strategy WireBytes (no artifacts needed)")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--probe-dir", default="results/probes")
    ap.add_argument("--out", default="results/roofline.json")
    a = ap.parse_args()
    if a.dpmr:
        print_dpmr_table(dpmr_rows())
    else:
        rows = analyze(a.dryrun_dir, a.probe_dir, a.out)
        if rows:
            print_table(rows)
