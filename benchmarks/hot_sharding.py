"""Paper §4 analogue: Zipf-head handling vs shuffle skew.

The paper splits high-frequency features into sub-features so no reducer's
line exceeds a block; our adaptation replicates the head. This benchmark
sweeps the hot-set size and reports (a) capacity-overflow count at a tight
capacity factor, (b) the max/mean owner-load imbalance, (c) effective a2a
bytes — the three faces of the same skew.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.api import get_strategy
from repro.api.strategies import StrategyContext
from repro.core import hot_sharding, sparse


def run(f: int = 1 << 16, p: int = 64, n: int = 1 << 15,
        zipf_alpha: float = 1.1, cap_factor: float = 1.5):
    rng = np.random.default_rng(0)
    raw = rng.zipf(zipf_alpha, size=n).astype(np.int64)
    ids_np = (((raw - 1) % f) * np.int64(2654435761) % f).astype(np.int32)
    ids = jnp.asarray(ids_np)
    block = f // p
    # capacity sized against the UNIQUE mean (the combiner dedups), so the
    # Zipf head's owner is the one that overflows
    uniq = len(np.unique(ids_np))
    mean = max(1, uniq // p)
    cap = max(16, int(cap_factor * mean))

    counts = hot_sharding.feature_counts(ids, f)
    rows = []
    for max_hot in (0, 16, 64, 256, 1024):
        if max_hot:
            hot = hot_sharding.select_hot(counts, 1e-4, max_hot)
            _, is_hot, cold = hot_sharding.split_hot(ids, hot)
            n_hot = int(jnp.sum(is_hot))
        else:
            cold, n_hot = ids, 0
        r = sparse.route_build(cold, p, block, cap)
        imb = float(hot_sharding.load_imbalance(cold, p, block))
        ctx = StrategyContext(axes=(), num_shards=p, block_size=block,
                              capacity=cap)
        a2a_bytes = get_strategy("a2a").bytes_per_device(ctx).total
        rows.append({"max_hot": max_hot, "hot_hits": n_hot,
                     "overflow": int(r.overflow), "imbalance": imb,
                     "a2a_bytes": a2a_bytes})
    return rows


def main():
    rows = run()
    print(f"{'max_hot':>8s} {'hot_hits':>9s} {'overflow':>9s} "
          f"{'imbalance':>10s} {'a2a_bytes':>10s}")
    for r in rows:
        print(f"{r['max_hot']:>8d} {r['hot_hits']:>9d} {r['overflow']:>9d} "
              f"{r['imbalance']:>10.2f} {r['a2a_bytes']:>10d}")
    return rows


if __name__ == "__main__":
    main()
