"""Hillclimb bookkeeping: compare a variant probe against the baseline and
emit the EXPERIMENTS.md §Perf row (hypothesis -> before -> after)."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.roofline import (CHIPS, PEAK_FLOPS_BF16, extrapolate,
                                 model_flops, roofline_terms)


def load(arch: str, shape: str, tag: str = "", d="results/probes"):
    suffix = f"probe_{tag}" if tag else "probe"
    path = os.path.join(d, f"{arch}__{shape}__{suffix}.json")
    probe = json.load(open(path))
    assert probe.get("status") == "ok", (path, probe.get("status"))
    step = extrapolate(probe)
    terms = roofline_terms(step)
    mf = model_flops(arch, shape, probe["kind"])
    ideal = mf / CHIPS / PEAK_FLOPS_BF16
    dom = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return {**terms, "collective_bytes": step["collective_bytes"],
            "flops_dev": step["flops"],
            "roofline_fraction": ideal / dom if dom else 0.0}


def compare(arch: str, shape: str, tags):
    base = load(arch, shape)
    print(f"== {arch} x {shape}")
    hdr = (f"{'variant':<14s} {'compute_s':>10s} {'memory_s':>9s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'roofl%':>7s} "
           f"{'dom delta':>10s}")
    print(hdr)

    def row(name, r, base_dom):
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        delta = "" if base_dom is None else f"{(dom/base_dom-1)*100:+.1f}%"
        print(f"{name:<14s} {r['compute_s']:>10.3f} {r['memory_s']:>9.3f} "
              f"{r['collective_s']:>10.3f} {r['dominant']:>10s} "
              f"{100*r['roofline_fraction']:>6.1f}% {delta:>10s}")
        return dom

    base_dom = row("baseline", base, None)
    out = {"baseline": base}
    for tag in tags:
        try:
            r = load(arch, shape, tag)
            row(tag, r, base_dom)
            out[tag] = r
        except (FileNotFoundError, AssertionError) as e:
            print(f"{tag:<14s} (missing: {e})")
    return out


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="single cell: compare this arch (with --shape)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--tags", nargs="*", default=["cp"])
    args = ap.parse_args()
    if args.arch:
        compare(args.arch, args.shape, args.tags)
        return
    compare("llama3-405b", "train_4k", ["cp", "cp_mb8"])
    compare("phi3.5-moe-42b-a6.6b", "train_4k", ["cp", "cp_g256"])
    compare("whisper-small", "train_4k", ["cp", "cp_mb4"])


if __name__ == "__main__":
    main()
