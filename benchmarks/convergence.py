"""Figure 1 analogue: per-class precision / recall / F vs iteration.

The paper's Figure 1 shows, on a ~3:1 imbalanced binary task, both classes
reaching a stable P/R/F plateau within ~2 full-batch iterations (iteration 1
biased toward the majority class, iteration 2 the refinement). We reproduce
the same curve shape on the synthetic Zipf corpus: majority class first,
minority class catching up, both converging toward the Bayes ceiling of the
generator. Reported: cate+1, cate-1 and avg for P, R, F per iteration —
exactly the paper's panels. Runs through `DPMREngine` and the `repro.data`
loader plane; run()'s `distribution` arg selects any registered strategy.
"""
from __future__ import annotations

from repro.api import (DPMREngine, ShardedLoader, get_source,
                       hot_ids_from_corpus)
from repro.configs.base import DPMRConfig
from repro.launch.mesh import make_host_mesh


def run(iterations: int = 8, optimizer: str = "adagrad", lr: float = 2.0,
        features: int = 1 << 14, distribution: str = "a2a"):
    corpus = dict(num_features=features, features_per_sample=32,
                  signal_features=512, seed=0)
    cfg = DPMRConfig(num_features=features, max_features_per_sample=32,
                     iterations=iterations, learning_rate=lr,
                     max_hot=64, optimizer=optimizer,
                     distribution=distribution)
    mesh = make_host_mesh(1, 1)
    train = ShardedLoader(get_source("zipf_sparse", batch_size=512,
                                     num_batches=8, **corpus), mesh)
    test = ShardedLoader(get_source("zipf_sparse", batch_size=512,
                                    num_batches=4, start=50, **corpus), mesh)
    hot = hot_ids_from_corpus(cfg, train.source.iter_batches(), mesh)

    engine = DPMREngine(cfg, mesh, hot_ids=hot)
    return engine.fit(train, eval_fn=lambda e: e.evaluate(test))


def main():
    hist = run()
    cols = ("precision_pos", "precision_neg", "precision_avg",
            "recall_pos", "recall_neg", "recall_avg",
            "f_pos", "f_neg", "f_avg")
    print("iter,loss," + ",".join(cols))
    for h in hist:
        print(f"{h['iteration']},{h['loss']:.4f}," +
              ",".join(f"{h[c]:.4f}" for c in cols))
    return hist


if __name__ == "__main__":
    main()
