"""Shard-ownership benchmark: file opens per host + load imbalance.

The paper's locality premise: each node maps only over the sample shards
it owns. Before ownership, `ShardedLoader` strode over *batches* (host h
read batches h, h+H, ...), so every host opened every chunk file — C opens
per host, H·C across the job. With chunk-aligned ownership each host opens
only its own ⌈C/H⌉ files. This benchmark measures both modes on a real
`file_sparse` corpus and emits `BENCH_shard_ownership.json` with the
shared envelope (`name` / `config` / `results`):

  files_opened    per-host unique chunk files touched over one epoch,
                  stride baseline vs ownership (target: C -> ~C/H)
  read_amplification
                  total chunk loads across hosts / C (stride pays ~H x,
                  ownership pays 1 x)
  load_imbalance  max/mean owned batches per host (chunk granularity
                  costs imbalance when C % H != 0 — the locality price)
  epoch_wall_s    wall-clock for every host to drain one epoch
                  sequentially (single-process simulation; the file-read
                  savings dominate on a cold page cache)

    PYTHONPATH=src python benchmarks/shard_ownership.py
    PYTHONPATH=src python benchmarks/shard_ownership.py --chunks 32 \
        --hosts 1 2 4 8
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from repro.data import ShardedLoader, get_source, write_file_corpus


def _drain_epoch(directory: str, host: int, hosts: int, ownership: str):
    """One host's epoch over a FRESH source; returns its read stats +
    batches served + wall time."""
    src = get_source("file_sparse", directory=directory, cache_chunks=2)
    loader = ShardedLoader(src, placement="host", prefetch=0,
                           host_index=host, num_hosts=hosts,
                           ownership=ownership)
    t0 = time.perf_counter()
    served = sum(1 for _ in loader.epoch())
    wall = time.perf_counter() - t0
    return {"host": host, "batches": served, "wall_s": wall,
            **src.read_stats}


def _mode_rows(directory: str, hosts: int, num_chunks: int, ownership: str):
    per_host = [_drain_epoch(directory, h, hosts, ownership)
                for h in range(hosts)]
    opened = [r["unique_chunks"] for r in per_host]
    batches = [r["batches"] for r in per_host]
    mean_b = sum(batches) / len(batches)
    return {
        "files_opened_per_host": opened,
        "max_files_opened": max(opened),
        "read_amplification": sum(r["chunk_loads"] for r in per_host)
        / num_chunks,
        "batches_per_host": batches,
        "load_imbalance": max(batches) / mean_b if mean_b else float("inf"),
        "epoch_wall_s": round(sum(r["wall_s"] for r in per_host), 4),
    }


def run(num_chunks: int = 16, batches_per_chunk: int = 4,
        batch_size: int = 256, hosts=(1, 2, 4), log2_features: int = 14,
        write_json: bool = True) -> dict:
    f = 1 << log2_features
    num_batches = num_chunks * batches_per_chunk
    tmp = tempfile.mkdtemp(prefix="repro_shard_ownership_")
    results = {"sweep": []}
    try:
        write_file_corpus(
            tmp, get_source("zipf_sparse", batch_size=batch_size,
                            num_batches=num_batches, num_features=f,
                            features_per_sample=32),
            batches_per_chunk=batches_per_chunk)
        for h in hosts:
            owned = _mode_rows(tmp, h, num_chunks, "auto")
            stride = _mode_rows(tmp, h, num_chunks, "stride")
            ceil_ch = -(-num_chunks // h)
            assert owned["max_files_opened"] == ceil_ch, (
                "ownership must open exactly the owned ceil(C/H) range",
                h, owned)
            # the stride baseline touches every chunk containing one of this
            # host's strided batch indices — the full corpus whenever
            # H <= batches_per_chunk, fewer (but always >= ownership) when
            # the stride jumps whole chunks
            spe = (num_batches // h) * h
            stride_expect = max(
                len({i // batches_per_chunk for i in range(hh, spe, h)})
                for hh in range(h))
            assert stride["max_files_opened"] == stride_expect, (
                "stride baseline open count mismatch", h, stride)
            assert stride["max_files_opened"] >= owned["max_files_opened"], (
                h, stride, owned)
            results["sweep"].append({
                "hosts": h, "chunks": num_chunks,
                "owned_files_per_host": ceil_ch,
                "ownership": owned, "stride_baseline": stride,
                "open_reduction": stride["max_files_opened"]
                / owned["max_files_opened"],
            })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # headline number for the CI regression gate (check_bench --compare):
    # the best per-host open-count reduction over the host sweep — analytic
    # (pure chunk arithmetic), so the 20% threshold flags real ownership
    # regressions, not runner noise
    results["max_open_reduction"] = max(
        row["open_reduction"] for row in results["sweep"])
    out = {
        "name": "shard_ownership",
        "config": {"chunks": num_chunks,
                   "batches_per_chunk": batches_per_chunk,
                   "num_batches": num_batches, "batch_size": batch_size,
                   "num_features": f, "hosts": list(hosts)},
        "primary_metric": {"path": "results.max_open_reduction",
                           "higher_is_better": True},
        "results": results,
    }
    if write_json:
        with open("BENCH_shard_ownership.json", "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--batches-per-chunk", type=int, default=4)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--hosts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--log2-features", type=int, default=14)
    args = ap.parse_args()
    out = run(num_chunks=args.chunks,
              batches_per_chunk=args.batches_per_chunk,
              batch_size=args.batch, hosts=tuple(args.hosts),
              log2_features=args.log2_features)
    print(f"{'hosts':>6s} {'opens/host own':>15s} {'opens/host stride':>18s} "
          f"{'read amp own':>13s} {'read amp stride':>16s} "
          f"{'imbalance':>10s}")
    for row in out["results"]["sweep"]:
        o, s = row["ownership"], row["stride_baseline"]
        print(f"{row['hosts']:>6d} {o['max_files_opened']:>15d} "
              f"{s['max_files_opened']:>18d} {o['read_amplification']:>13.2f} "
              f"{s['read_amplification']:>16.2f} {o['load_imbalance']:>10.3f}")
    print("wrote BENCH_shard_ownership.json")
    return out


if __name__ == "__main__":
    main()
