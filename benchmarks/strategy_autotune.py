"""Analytic geometry-autotuner benchmark (the `distribution="auto"` story).

Prices every registered strategy and composition on the paper's production
geometries with `repro.api.autotune` — each tier's audited `WireBytes`
charged at that tier's bandwidth (ICI ~10x DCN) — and pins what the tuner
buys:

  rankings     the full ranked table per mesh (single-pod 256, 2-pod 512):
               bytes per tier, wire-cost seconds, rank, the winner.
  headline     on the 512-shard production geometry the tuned choice must
               be STRICTLY cheaper than the paper-faithful flat `a2a`. The
               reduction is the primary metric. Note it is a wire-COST
               (seconds) ratio, not a byte ratio: the hierarchical family
               deliberately spends MORE ICI bytes to dodge DCN, so only
               bandwidth-weighted cost makes the comparison meaningful.
  bw sweep     chosen strategy as the ICI:DCN bandwidth ratio sweeps from
               1x to 100x — shows the choice flipping from the flat
               exchange (uniform fabric) to the composed hierarchical
               family as DCN gets relatively slower, and documents the
               monotonicity the hypothesis suite proves in general.

Everything here is analytic (wire models + arithmetic, no compilation),
so the output is DETERMINISTIC — `scripts/check_bench.py --compare` gates
the primary metric against the committed baseline in nightly CI at the
20% threshold, meaning a flagged change is a real wire-model or tuner
change, never runner noise.

Emits `BENCH_strategy_autotune.json` (shared envelope: `name` / `config` /
`results`, validated by `scripts/check_bench.py`).

Run: PYTHONPATH=src python benchmarks/strategy_autotune.py
"""
from __future__ import annotations

import json

from repro.api import autotune
from repro.api.strategies import StrategyContext
from repro.configs.base import DPMRConfig
from repro.core import dpmr

# paper-regime headline geometries (make_production_mesh shapes)
P_SINGLE, P_MULTI, PODS = 256, 512, 2
GLOBAL_BATCH = 1 << 24
K = 64
FEATURES = 1 << 30

BW_RATIOS = (1, 2, 5, 10, 20, 50, 100)   # ICI:DCN speed ratio sweep


def _ctx(p: int, po: int) -> StrategyContext:
    cfg = DPMRConfig(num_features=FEATURES, max_features_per_sample=K)
    cap = dpmr.capacity_for_shards(cfg, GLOBAL_BATCH // p, p)
    return StrategyContext(axes=(), num_shards=p,
                           block_size=-(-FEATURES // p), capacity=cap,
                           outer_shards=po, topk_frac=cfg.topk_frac)


def ranking_rows(ctx: StrategyContext, mesh_kind: str) -> list:
    rows = []
    for rank, s in enumerate(autotune.score_strategies(ctx), start=1):
        rows.append({"mesh": mesh_kind, "strategy": s.name, "rank": rank,
                     "inner_bytes": int(s.wire.inner),
                     "outer_bytes": int(s.wire.outer),
                     "total_bytes": int(s.wire.total),
                     "cost_us": s.cost_s * 1e6, "lossy": s.lossy})
    return rows


def bandwidth_sweep(ctx: StrategyContext) -> list:
    """Chosen strategy per ICI:DCN ratio (inner speed fixed)."""
    rows = []
    for ratio in BW_RATIOS:
        bw = autotune.WireBandwidth(inner_gbps=900.0,
                                    outer_gbps=900.0 / ratio)
        ranked = autotune.score_strategies(ctx, bw)
        rows.append({"ici_dcn_ratio": ratio, "chosen": ranked[0].name,
                     "chosen_cost_us": ranked[0].cost_s * 1e6,
                     "a2a_cost_us": next(s for s in ranked
                                         if s.name == "a2a").cost_s * 1e6})
    return rows


def run(write_json: bool = True) -> dict:
    ctx_multi = _ctx(P_MULTI, PODS)
    multi = ranking_rows(ctx_multi, "multi")
    single = ranking_rows(_ctx(P_SINGLE, 1), "single")

    tuned = multi[0]
    a2a = next(r for r in multi if r["strategy"] == "a2a")
    reduction_x = a2a["cost_us"] / tuned["cost_us"]
    assert reduction_x > 1.0, (
        "the tuned choice must be strictly cheaper than flat a2a on the "
        "production geometry", tuned, a2a)
    assert tuned["strategy"] == autotune.choose_strategy(ctx_multi), multi

    sweep = bandwidth_sweep(ctx_multi)
    # the sweep must actually flip: a uniform fabric has no reason to pay
    # the hierarchical family's extra ICI volume, a 10x-skewed one does
    assert sweep[0]["chosen"] != sweep[-1]["chosen"], sweep

    out = {
        "name": "strategy_autotune",
        "config": {"shards_single": P_SINGLE, "shards_multi": P_MULTI,
                   "pods": PODS, "global_batch": GLOBAL_BATCH,
                   "features": FEATURES, "features_per_sample": K,
                   "inner_gbps": autotune.WireBandwidth().inner_gbps,
                   "outer_gbps": autotune.WireBandwidth().outer_gbps,
                   "bw_ratios": list(BW_RATIOS)},
        # consumed by scripts/check_bench.py --compare (nightly CI gate):
        # the analytic wire-cost reduction of the tuned choice vs flat a2a
        # on the 512-shard production geometry — deterministic
        "primary_metric": {"path": "results.autotune_cost_reduction_x",
                           "higher_is_better": True},
        "results": {
            "tuned_choice": tuned["strategy"],
            "autotune_cost_reduction_x": reduction_x,
            "tuned_cost_us": tuned["cost_us"],
            "a2a_cost_us": a2a["cost_us"],
            "ranking_multi": multi,
            "ranking_single": single,
            "bandwidth_sweep": sweep,
        },
    }
    if write_json:
        with open("BENCH_strategy_autotune.json", "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def main():
    out = run()
    res = out["results"]
    for rows in (res["ranking_single"], res["ranking_multi"]):
        print(f"{'mesh':>7s} {'strategy':>18s} {'ICI B/dev':>12s} "
              f"{'DCN B/dev':>12s} {'cost us':>9s} {'rank':>4s}")
        for r in rows:
            mark = " *" if r["rank"] == 1 else ""
            print(f"{r['mesh']:>7s} {r['strategy']:>18s} "
                  f"{r['inner_bytes']:>12.3e} {r['outer_bytes']:>12.3e} "
                  f"{r['cost_us']:>9.1f} {r['rank']:>4d}{mark}")
        print()
    print("ICI:DCN bandwidth-ratio sweep (production geometry):")
    for r in res["bandwidth_sweep"]:
        print(f"  {r['ici_dcn_ratio']:>4d}x -> {r['chosen']:<18s} "
              f"{r['chosen_cost_us']:>8.1f} us (a2a {r['a2a_cost_us']:.1f})")
    print(f"\ntuned choice on 512 shards: {res['tuned_choice']} — "
          f"x{res['autotune_cost_reduction_x']:.2f} cheaper wire than a2a")
    print("wrote BENCH_strategy_autotune.json")
    return out


if __name__ == "__main__":
    main()
