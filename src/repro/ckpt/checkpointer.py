"""Checkpointing: atomic, versioned, async-capable, elastic on restore.

Layout:   <dir>/step_<N>/
            manifest.json        # tree structure, shapes, dtypes, step, data state
            arr_<i>.npy          # one file per leaf (full logical array)

Guarantees:
  - atomicity, twice over: leaves land in `step_<N>.tmp` which is
    os.replace'd into place only when complete, and INSIDE the directory
    the manifest itself is written to a temp name, fsync'd, and
    os.replace'd last — so a complete `manifest.json` is the definition
    of a complete checkpoint. Discovery (`all_steps`) only counts step
    directories whose manifest parses: a crash mid-write (or a truncated
    manifest from any other writer) makes that step invisible and restore
    falls back to the previous good one instead of crashing.
  - keep-N retention.
  - elastic restore: leaves are FULL logical arrays; `restore` device_puts
    them under whatever shardings the NEW mesh prescribes, so a run saved on
    a (16,16) mesh restarts on (8,16) or (2,16,16) unchanged (DPMR sparse
    state needs re-padding — runtime/elastic.py; `restore_host` hands back
    the raw host arrays for that path).
  - async: `save(..., block=False)` keeps only the device->host snapshot on
    the step path (the leaves are host copies the moment save() returns, so
    later donation/mutation of the live buffers cannot leak into the file)
    and does serialization + fsync + the atomic renames on a daemon thread;
    `wait()` joins before the next save or process exit.

Multi-process: under real `jax.distributed` execution every process calls
`save` (the host gather of cross-process arrays is a collective —
`runtime/multiprocess.host_value`), but only process 0 touches the
filesystem; the directory is expected to be shared (or only process 0's
copy is the checkpoint of record). Restore reads full logical arrays on
every process and device_puts them under the global shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro.runtime import multiprocess


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, extra: dict | None = None,
             block: bool = True):
        """Snapshot `state` (pytree of jax/np arrays) at `step`.

        The device->host copy happens HERE, synchronously — that is the
        snapshot point, and the only work `block=False` leaves on the step
        path. Everything after (np.save, manifest fsync, atomic renames,
        GC) runs inline (`block=True`) or on a daemon thread."""
        self.wait()
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [multiprocess.host_value(l) for l in leaves]
        manifest = {
            "step": int(step),
            "num_leaves": len(leaves),
            "paths": [str(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(state)[0]],
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "extra": extra or {},
            "time": time.time(),
        }
        if not multiprocess.is_primary():
            return      # gather above was the collective part; 0 writes

        def _write():
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            # manifest last, via its own temp + replace: its presence (and
            # parseability) is the completeness marker readers trust
            mtmp = os.path.join(tmp, "manifest.json.tmp")
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(mtmp, os.path.join(tmp, "manifest.json"))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if block:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}", "manifest.json")

    def _manifest_ok(self, step: int) -> bool:
        try:
            with open(self._manifest_path(step)) as f:
                json.load(f)
            return True
        except (OSError, ValueError):
            return False

    def all_steps(self) -> list[int]:
        """Steps with a COMPLETE checkpoint (parseable manifest). A dir
        whose manifest is missing or truncated — a crashed writer, a
        partial copy — is skipped, so `restore()` falls back to the
        newest good step instead of crashing on the bad one."""
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    step = int(name[5:])
                except ValueError:
                    continue
                if self._manifest_ok(step):
                    out.append(step)
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_host(self, step: int | None = None
                     ) -> tuple[list[np.ndarray], dict]:
        """Raw host-side leaves + manifest, no placement — the elastic
        path: when the saved geometry no longer matches the live state
        (`shapes` differ), re-pad/re-shard these with
        `runtime/elastic.py` instead of device_putting them blind."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with open(self._manifest_path(step)) as f:
            manifest = json.load(f)
        d = os.path.join(self.dir, f"step_{step:010d}")
        arrs = [np.load(os.path.join(d, f"arr_{i}.npy"))
                for i in range(manifest["num_leaves"])]
        return arrs, manifest

    def restore(self, like, step: int | None = None,
                shardings=None):
        """Restore into the structure of `like` (pytree). If `shardings` is
        given (pytree of NamedSharding matching `like`), leaves are placed
        under them — this is the elastic-resharding path."""
        arrs, manifest = self.restore_host(step)
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == manifest["num_leaves"], (
            len(leaves), manifest["num_leaves"])
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings)
            out = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves, strict=True)]
        else:
            out = [jax.device_put(a, l.sharding)
                   if isinstance(l, jax.Array) else jax.numpy.asarray(a)
                   for a, l in zip(arrs, leaves, strict=True)]
        return jax.tree.unflatten(treedef, out), manifest


def manifest_extra(directory: str, step: int | None = None) -> dict:
    ck = Checkpointer(directory)
    step = ck.latest_step() if step is None else step
    with open(os.path.join(directory, f"step_{step:010d}",
                           "manifest.json")) as f:
        return json.load(f)["extra"]
