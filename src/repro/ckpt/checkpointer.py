"""Checkpointing: atomic, versioned, async-capable, elastic on restore.

Layout:   <dir>/step_<N>/
            manifest.json        # tree structure, shapes, dtypes, step, data state
            arr_<i>.npy          # one file per leaf (full logical array)

Guarantees:
  - atomicity: written to `step_<N>.tmp`, fsync'd, then os.replace'd — a
    crash mid-write never corrupts the latest checkpoint.
  - keep-N retention.
  - elastic restore: leaves are FULL logical arrays; `restore` device_puts
    them under whatever shardings the NEW mesh prescribes, so a run saved on
    a (16,16) mesh restarts on (8,16) or (2,16,16) unchanged (DPMR sparse
    state needs re-padding — runtime/elastic.py).
  - async: `save(..., block=False)` gathers to host synchronously (cheap)
    and writes on a daemon thread; `wait()` joins before the next save.

Multi-host note: this implementation writes full logical arrays from one
process (this container is single-process). The layout is per-leaf files +
manifest precisely so a multi-host deployment can switch to per-shard files
(`arr_<i>.shard<k>.npy` + process-local writes) without changing readers.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, extra: dict | None = None,
             block: bool = True):
        """Snapshot `state` (pytree of jax/np arrays) at `step`."""
        self.wait()
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        manifest = {
            "step": int(step),
            "treedef": jax.tree.unflatten(
                treedef, list(range(len(leaves)))) if False else None,
            "num_leaves": len(leaves),
            "paths": [str(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(state)[0]],
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "extra": extra or {},
            "time": time.time(),
        }

        def _write():
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if block:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None,
                shardings=None):
        """Restore into the structure of `like` (pytree). If `shardings` is
        given (pytree of NamedSharding matching `like`), leaves are placed
        under them — this is the elastic-resharding path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == manifest["num_leaves"], (
            len(leaves), manifest["num_leaves"])
        arrs = [np.load(os.path.join(d, f"arr_{i}.npy"))
                for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings)
            out = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves, strict=True)]
        else:
            out = [jax.device_put(a, l.sharding)
                   if isinstance(l, jax.Array) else jax.numpy.asarray(a)
                   for a, l in zip(arrs, leaves, strict=True)]
        return jax.tree.unflatten(treedef, out), manifest


def manifest_extra(directory: str, step: int | None = None) -> dict:
    ck = Checkpointer(directory)
    step = ck.latest_step() if step is None else step
    with open(os.path.join(directory, f"step_{step:010d}",
                           "manifest.json")) as f:
        return json.load(f)["extra"]
