"""DPMR dense face: fully-sharded parameters as the degenerate map-reduce.

When every sample touches every parameter (a dense layer), the paper's
inverted index is trivial — every feature's sample list is "all docs" — and
the DPMR stages collapse to:

    distributeParameters  ->  all_gather(param shard)   [per layer, in scan]
    restoreDocuments      ->  identity (already aligned)
    computeGradients      ->  local matmul fwd/bwd
    reduce shuffle        ->  reduce_scatter(grad)
    updateParameters      ->  sharded optimizer step

i.e. DPMR-on-dense IS ZeRO-3/FSDP. The model zoo gets this implicitly from
GSPMD via the `embed -> data` logical-axis rule (repro.sharding); this module
provides the EXPLICIT shard_map reference used by the tests to prove the
implicit path computes the paper's pipeline, plus `dpmr_dense_linear`, a
drop-in FSDP linear whose collectives are hand-placed (useful for perf
iteration when XLA's choices are suboptimal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def dpmr_dense_linear_ref(w_shard, x, axis: str):
    """Explicit DPMR stages for y = x @ W with W row-sharded over `axis`.

    Per-device: w_shard (D/P, F), x (B_loc, D) [batch sharded elsewhere or
    replicated]. Returns y (B_loc, F). For use inside shard_map.
    """
    # distributeParameters: materialize the full W on each node
    w_full = jax.lax.all_gather(w_shard, axis, tiled=True)          # (D, F)
    # computeGradients map body (forward part)
    return jnp.dot(x, w_full, preferred_element_type=jnp.float32)


def dpmr_dense_grad_ref(w_shard, x, gy, axis: str):
    """Backward: gw = x^T gy, reduced back to the owner shard
    (the reduce-by-feature stage)."""
    gw_full = jnp.dot(x.T, gy, preferred_element_type=jnp.float32)  # (D, F)
    # reduce shuffle: every node holds a partial sum over ITS samples;
    # reduce_scatter delivers summed rows to their owners
    return jax.lax.psum_scatter(gw_full, axis, scatter_dimension=0,
                                tiled=True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def dpmr_dense_linear(w_shard, x, axis: str):
    """Differentiable explicit-FSDP linear (shard_map context required)."""
    return dpmr_dense_linear_ref(w_shard, x, axis)


def _fwd(w_shard, x, axis):
    return dpmr_dense_linear_ref(w_shard, x, axis), (w_shard, x)


def _bwd(axis, res, gy):
    w_shard, x = res
    gw_shard = dpmr_dense_grad_ref(w_shard, x, gy, axis)
    # dx needs the full W again (re-gather; remat-style, no stored full W)
    w_full = jax.lax.all_gather(w_shard, axis, tiled=True)
    gx = jnp.dot(gy, w_full.T, preferred_element_type=jnp.float32)
    return gw_shard.astype(w_shard.dtype), gx.astype(x.dtype)


dpmr_dense_linear.defvjp(_fwd, _bwd)


def fsdp_specs(defs_tree, mesh) -> tuple:
    """(sharding specs, shardings) for a parameter def tree — the dense-face
    storage layout (delegates to the logical-axis rules)."""
    from repro import sharding as shd

    return shd.tree_specs(defs_tree, mesh), shd.tree_shardings(defs_tree, mesh)
