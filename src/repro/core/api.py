"""Legacy public API of the DPMR core.

Prefer `repro.api` (the typed `DPMREngine` façade + strategy registry);
this module keeps the flat re-exports working for one release. The training
entry points re-exported from `core.sparse_lr` emit DeprecationWarnings —
see that module's docstring for the old→new migration table.
"""
from repro.core.dpmr import (
    DPMRState,
    StepFns,
    capacity,
    init_state,
    make_schedule,
    make_step_fns,
    num_shards,
    optimize,
    padded_features,
)
from repro.core.fsdp import dpmr_dense_linear, fsdp_specs
from repro.core.hot_sharding import (
    feature_counts,
    load_imbalance,
    select_hot,
    split_hot,
)
from repro.core.sparse import (
    Routing,
    combine_grads,
    owner_accumulate,
    owner_apply,
    route_build,
    route_return,
)
from repro.core.sparse_lr import (
    dpmr_classify,
    dpmr_train,
    dpmr_train_sgd,
    evaluate,
    hot_ids_from_corpus,
)

__all__ = [
    "DPMRState", "Routing", "StepFns", "capacity", "combine_grads",
    "dpmr_classify", "dpmr_dense_linear", "dpmr_train", "dpmr_train_sgd",
    "evaluate", "feature_counts", "fsdp_specs", "hot_ids_from_corpus",
    "init_state", "load_imbalance", "make_schedule", "make_step_fns",
    "num_shards", "optimize", "owner_accumulate", "owner_apply",
    "padded_features", "route_build", "route_return", "select_hot",
    "split_hot",
]
