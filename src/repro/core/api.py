"""Legacy public API of the DPMR core.

Prefer `repro.api` (the typed `DPMREngine` façade + strategy registry) and
`repro.data` (the DataSource registry + ShardedLoader); this module keeps
flat re-exports of the core primitives working. The deprecated fn-dict
training entry points (`dpmr_train`, `dpmr_train_sgd`, `dpmr_classify`,
`evaluate` from the old `core.sparse_lr`) completed their one-release
deprecation and were REMOVED — see the migration table in CHANGES.md.
"""
from repro.api.engine import hot_ids_from_corpus
from repro.core.dpmr import (
    DPMRState,
    StepFns,
    capacity,
    init_state,
    make_schedule,
    make_step_fns,
    num_shards,
    optimize,
    padded_features,
)
from repro.core.fsdp import dpmr_dense_linear, fsdp_specs
from repro.core.hot_sharding import (
    feature_counts,
    load_imbalance,
    select_hot,
    split_hot,
)
from repro.core.sparse import (
    Routing,
    combine_grads,
    owner_accumulate,
    owner_apply,
    route_build,
    route_return,
)

__all__ = [
    "DPMRState", "Routing", "StepFns", "capacity", "combine_grads",
    "dpmr_dense_linear", "feature_counts", "fsdp_specs",
    "hot_ids_from_corpus", "init_state", "load_imbalance", "make_schedule",
    "make_step_fns", "num_shards", "optimize", "owner_accumulate",
    "owner_apply", "padded_features", "route_build", "route_return",
    "select_hot", "split_hot",
]
