"""Sparse batch format + feature routing math for the DPMR sparse face.

This module is pure per-device math (no collectives), so every function has
a numpy-checkable oracle in the tests. The engine (core.dpmr) wraps these in
shard_map with all_to_all between the routing phases.

Terminology maps to the paper:
  - `route_build`    = invertDocuments + the combiner (duplicate features in a
                       shard are deduplicated before requesting — Algorithm 3's
                       combiner) + the shuffle layout of distributeParameters.
  - `route_return`   = restoreDocuments (responses land request-aligned; the
                       unsort restores the original sample layout).
  - `combine_grads`  = computeGradients' combiner (sum per feature before the
                       reduce-side shuffle).

Feature ownership is contiguous-block: owner(f) = f // block_size, so a sort
by feature id simultaneously groups by owner (monotone) and makes duplicates
adjacent — one sort serves both the shuffle and the combiner.

Batches are padded CSR: ids (B, K) int32 with -1 padding, vals (B, K) f32,
labels (B,) int32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Routing(NamedTuple):
    """Static-shape routing plan for one device's feature slots."""

    req_ids: jax.Array       # (P, cap) int32, -1 = empty: ids requested per owner
    order: jax.Array         # (n,) argsort-by-id permutation (sorted <- orig)
    owner_s: jax.Array       # (n,) owner of each sorted slot (P = padding)
    pos_s: jax.Array         # (n,) capacity slot of the run containing slot
    keep_s: jax.Array        # (n,) bool: run fits in capacity and is real
    start_idx_s: jax.Array   # (n,) sorted index of the run start for each slot
    overflow: jax.Array      # () int32: dropped unique features (capacity)


def route_build(ids_flat: jax.Array, num_shards: int, block_size: int,
                cap: int) -> Routing:
    """Build the request plan. ids_flat: (n,) int32 with -1 for padding."""
    n = ids_flat.shape[0]
    valid = ids_flat >= 0
    owner = jnp.where(valid, ids_flat // block_size, num_shards)
    # sort by id; padding (-1) would sort first, so remap padding to +inf-ish
    sort_key = jnp.where(valid, ids_flat, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(sort_key, stable=True)
    ids_s = sort_key[order]
    owner_s = owner[order]
    valid_s = valid[order]

    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]]) & valid_s
    u = jnp.cumsum(is_start.astype(jnp.int32))          # runs up to & incl. i
    # owner o's first sorted index
    owner_first = jnp.searchsorted(owner_s, jnp.arange(num_shards),
                                   side="left")
    runs_before_owner = u[jnp.clip(owner_first, 0, n - 1)] - \
        is_start[jnp.clip(owner_first, 0, n - 1)].astype(jnp.int32)
    runs_before_owner = jnp.where(owner_first >= n,
                                  u[-1], runs_before_owner)
    # capacity slot of each element's run, within its owner
    pos_s = (u - 1) - runs_before_owner[jnp.clip(owner_s, 0, num_shards - 1)]
    keep_s = valid_s & (pos_s < cap)

    # scatter unique run-start ids into the request matrix
    req = jnp.full((num_shards, cap), -1, jnp.int32)
    scat_owner = jnp.where(is_start & keep_s, owner_s, num_shards)
    scat_pos = jnp.where(is_start & keep_s, pos_s, 0)
    req = req.at[scat_owner, scat_pos].set(
        jnp.where(is_start & keep_s, ids_s, -1), mode="drop")

    # run-start sorted index for every slot (to copy responses to duplicates)
    start_idx = jnp.where(is_start, jnp.arange(n), -1)
    start_idx_s = jax.lax.cummax(start_idx)

    n_unique = u[-1]
    kept_unique = jnp.sum((is_start & keep_s).astype(jnp.int32))
    overflow = n_unique - kept_unique
    return Routing(req, order, owner_s, pos_s, keep_s, start_idx_s, overflow)


def route_return(routing: Routing, resp: jax.Array) -> jax.Array:
    """Map responses (P, cap) back to the original slot layout (n,).

    resp[o, c] is the value for the c-th unique feature requested from owner
    o. Every duplicate slot copies its run start's response; padding/overflow
    slots get 0.
    """
    n = routing.order.shape[0]
    gathered = resp[jnp.clip(routing.owner_s, 0, resp.shape[0] - 1),
                    routing.pos_s]
    gathered = jnp.where(routing.keep_s, gathered, 0.0)
    # propagate the run-start's value to duplicates; mask padding/overflow
    start_vals = gathered[jnp.clip(routing.start_idx_s, 0, n - 1)]
    vals_sorted = jnp.where(routing.keep_s, start_vals, 0.0)
    out = jnp.zeros((n,), resp.dtype)
    return out.at[routing.order].set(vals_sorted)


def combine_grads(routing: Routing, grads_flat: jax.Array) -> jax.Array:
    """Combiner: sum per-slot grads by feature -> (P, cap) send buffer.

    grads_flat: (n,) in the ORIGINAL slot layout. Output aligns with the
    request matrix (owner, capacity-slot), so the reverse all_to_all delivers
    per-unique-feature sums to owners.
    """
    g_sorted = grads_flat[routing.order]
    g_sorted = jnp.where(routing.keep_s, g_sorted, 0.0)
    send = jnp.zeros((routing.req_ids.shape[0], routing.req_ids.shape[1]),
                     grads_flat.dtype)
    scat_owner = jnp.where(routing.keep_s, routing.owner_s,
                           routing.req_ids.shape[0])
    return send.at[scat_owner, routing.pos_s].add(g_sorted, mode="drop")


def owner_apply(req_ids: jax.Array, table_local: jax.Array,
                base: jax.Array) -> jax.Array:
    """Owner side of distributeParameters: look up requested rows.

    req_ids: (P, cap) global ids (-1 empty); table_local: (rows,);
    base: scalar global id of local row 0. Returns (P, cap) values.
    """
    local = jnp.clip(req_ids - base, 0, table_local.shape[0] - 1)
    vals = table_local[local]
    return jnp.where(req_ids >= 0, vals, 0.0)


def owner_accumulate(req_ids: jax.Array, grads: jax.Array,
                     acc_local: jax.Array, base: jax.Array) -> jax.Array:
    """Owner side of the gradient reduce: scatter-add received sums."""
    local = jnp.where(req_ids >= 0, req_ids - base, acc_local.shape[0])
    return acc_local.at[local.reshape(-1)].add(
        jnp.where(req_ids >= 0, grads, 0.0).reshape(-1), mode="drop")
