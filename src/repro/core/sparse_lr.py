"""DEPRECATED fn-dict surface for distributed sparse logistic regression.

Everything here is a thin shim over `repro.api.DPMREngine` (the typed
façade) kept for one release so old call sites keep working:

  old                                   new
  ---                                   ---
  dpmr_train(cfg, mesh, it, bs)         DPMREngine(cfg, mesh).fit(it)
  dpmr_train_sgd(cfg, mesh, bs, n)      DPMREngine(cfg, mesh).fit_sgd(bs)
  dpmr_classify(state, fns, b, mesh)    engine.predict(b)
  evaluate(state, fns, tb, mesh)        engine.evaluate(tb)
  out["state"] / out["history"] /       engine.state / returned history /
  out["fns"]                            engine.fns

`hot_ids_from_corpus` and `_put_batch` are re-exported from their new home
in `repro.api.engine`.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Iterable, Optional

import numpy as np

from repro.api.engine import (binary_prf_metrics, hot_ids_from_corpus,
                              put_batch)
from repro.configs.base import DPMRConfig

__all__ = ["dpmr_classify", "dpmr_train", "dpmr_train_sgd", "evaluate",
           "hot_ids_from_corpus"]


def _put_batch(batch: dict, mesh) -> dict:
    return put_batch(batch, mesh)


def _deprecated(old: str, new: str):
    warnings.warn(f"repro.core.sparse_lr.{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


def _engine(cfg: DPMRConfig, mesh, hot_ids, kernel_impl: str):
    from repro.api import DPMREngine

    return DPMREngine(cfg, mesh, hot_ids=hot_ids, kernel_impl=kernel_impl)


def dpmr_train(cfg: DPMRConfig, mesh, batch_iter_fn: Callable[[], Iterable],
               batch_size: int, *, hot_ids=None, kernel_impl: str = "jnp",
               eval_fn: Optional[Callable] = None) -> Dict:
    """Deprecated: use DPMREngine(cfg, mesh).fit(batch_iter_fn)."""
    _deprecated("dpmr_train", "repro.api.DPMREngine.fit")
    eng = _engine(cfg, mesh, hot_ids, kernel_impl)
    wrapped = None if eval_fn is None else (
        lambda e: eval_fn(e.state, e.fns))
    history = eng.fit(batch_iter_fn, eval_fn=wrapped)
    return {"state": eng.state, "history": history,
            "fns": eng.step_fns(batch_size)}


def dpmr_train_sgd(cfg: DPMRConfig, mesh, batches: Iterable[dict],
                   batch_size: int, *, hot_ids=None,
                   kernel_impl: str = "jnp") -> Dict:
    """Deprecated: use DPMREngine(cfg, mesh).fit_sgd(batches)."""
    _deprecated("dpmr_train_sgd", "repro.api.DPMREngine.fit_sgd")
    eng = _engine(cfg, mesh, hot_ids, kernel_impl)
    history = eng.fit_sgd(batches)
    return {"state": eng.state, "history": history,
            "fns": eng.step_fns(batch_size)}


def dpmr_classify(state, fns, batch, mesh) -> np.ndarray:
    """Deprecated: use DPMREngine.predict(batch)."""
    _deprecated("dpmr_classify", "repro.api.DPMREngine.predict")
    probs = fns.predict(state, put_batch(batch, mesh))
    return np.asarray(probs)


def evaluate(state, fns, test_batches: Iterable[dict], mesh) -> Dict:
    """Deprecated: use DPMREngine.evaluate(test_batches)."""
    _deprecated("evaluate", "repro.api.DPMREngine.evaluate")

    def predict(batch):
        return np.asarray(fns.predict(state, put_batch(
            {k: batch[k] for k in ("ids", "vals")}, mesh)))

    return binary_prf_metrics(predict, test_batches)
