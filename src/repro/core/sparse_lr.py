"""Distributed sparse logistic regression on the DPMR engine.

`dpmr_train` is Algorithm 1/8 (full-batch GD over the corpus per iteration,
the paper's optimization regime); `dpmr_train_sgd` is the minibatch variant a
modern deployment would run. `dpmr_classify` is Algorithm 9 and
`evaluate` reproduces Figure 1's per-class precision / recall / F metrics.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DPMRConfig
from repro.core import dpmr, hot_sharding


def hot_ids_from_corpus(cfg: DPMRConfig, sample_batches: Iterable[dict],
                        mesh) -> jax.Array:
    """initParameters-time frequency statistics -> replicated hot set."""
    f = dpmr.padded_features(cfg, mesh)
    counts = jnp.zeros((f,), jnp.int32)
    for b in sample_batches:
        counts = counts + hot_sharding.feature_counts(
            jnp.asarray(b["ids"]), f)
    return hot_sharding.select_hot(counts, cfg.hot_threshold, cfg.max_hot)


def _put_batch(batch: dict, mesh) -> dict:
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(mesh.axis_names)
    out = {}
    for k, v in batch.items():
        out[k] = jax.device_put(jnp.asarray(v),
                                NamedSharding(mesh, P(axes)))
    return out


def dpmr_train(cfg: DPMRConfig, mesh, batch_iter_fn: Callable[[], Iterable],
               batch_size: int, *, hot_ids=None, kernel_impl: str = "jnp",
               eval_fn: Optional[Callable] = None) -> Dict:
    """Full-batch gradient descent: one parameter update per ITERATION
    (paper semantics). batch_iter_fn() yields the whole training corpus in
    fixed-size batches each time it is called."""
    fns = dpmr.make_step_fns(cfg, mesh, batch_size, kernel_impl)
    state = dpmr.init_state(cfg, mesh, hot_ids)
    history: List[Dict] = []
    for it in range(cfg.iterations):
        acc_cold = jnp.zeros_like(state.cold)
        acc_hot = jnp.zeros_like(state.hot)
        tot_loss, tot_acc, nb = 0.0, 0.0, 0
        for batch in batch_iter_fn():
            gb = _put_batch(batch, mesh)
            gc, gh, m = fns["grad_step"](state, gb)
            acc_cold = acc_cold + gc
            acc_hot = acc_hot + gh
            tot_loss += float(m["loss"])
            tot_acc += float(m["accuracy"])
            nb += 1
        state = fns["apply_update"](state, acc_cold / nb, acc_hot / nb,
                                    cfg.learning_rate)
        rec = {"iteration": it + 1, "loss": tot_loss / nb,
               "accuracy": tot_acc / nb}
        if eval_fn is not None:
            rec.update(eval_fn(state, fns))
        history.append(rec)
    return {"state": state, "history": history, "fns": fns}


def dpmr_train_sgd(cfg: DPMRConfig, mesh, batches: Iterable[dict],
                   batch_size: int, *, hot_ids=None,
                   kernel_impl: str = "jnp") -> Dict:
    """Minibatch SGD variant (one update per batch)."""
    fns = dpmr.make_step_fns(cfg, mesh, batch_size, kernel_impl)
    state = dpmr.init_state(cfg, mesh, hot_ids)
    history: List[Dict] = []
    for i, batch in enumerate(batches):
        state, m = fns["train_step"](state, _put_batch(batch, mesh))
        history.append({"step": i + 1, "loss": float(m["loss"]),
                        "accuracy": float(m["accuracy"]),
                        "overflow": int(m["overflow"])})
    return {"state": state, "history": history, "fns": fns}


def dpmr_classify(state, fns, batch, mesh) -> np.ndarray:
    """Algorithm 9: probabilities for a test batch."""
    probs = fns["predict"](state, _put_batch(batch, mesh))
    return np.asarray(probs)


def evaluate(state, fns, test_batches: Iterable[dict], mesh) -> Dict:
    """Fig. 1 metrics: per-class precision/recall/F + the macro average."""
    tp = fp = fn_ = tn = 0
    for batch in test_batches:
        probs = dpmr_classify(state, fns, {k: batch[k] for k in
                                           ("ids", "vals")}, mesh)
        pred = (probs >= 0.5).astype(np.int32)
        y = np.asarray(batch["labels"])
        tp += int(np.sum((pred == 1) & (y == 1)))
        fp += int(np.sum((pred == 1) & (y == 0)))
        fn_ += int(np.sum((pred == 0) & (y == 1)))
        tn += int(np.sum((pred == 0) & (y == 0)))

    def prf(tp, fp, fn):
        p = tp / max(tp + fp, 1)
        r = tp / max(tp + fn, 1)
        f = 2 * p * r / max(p + r, 1e-9)
        return p, r, f

    p1, r1, f1 = prf(tp, fp, fn_)
    p0, r0, f0 = prf(tn, fn_, fp)
    return {
        "precision_pos": p1, "recall_pos": r1, "f_pos": f1,
        "precision_neg": p0, "recall_neg": r0, "f_neg": f0,
        "precision_avg": (p1 + p0) / 2, "recall_avg": (r1 + r0) / 2,
        "f_avg": (f1 + f0) / 2,
    }
