"""Distributed Parameter Map-Reduce — the paper's engine on TPU collectives.

Algorithm 1/8 of the paper as a shard_map program over ALL mesh axes (every
device is a DPMR node holding both a sample shard and a parameter shard,
exactly the paper's HDFS co-location):

  stage                 paper          here (per train step)
  -----                 -----          ----
  initParameters        Algorithm 2    init_state (zeros; hot stats external)
  invertDocuments       Algorithm 3    sparse.route_build (sort-by-feature)
  distributeParameters  Algorithm 4    all_to_all(requests) + owner lookup
                                       + all_to_all(responses)
  restoreDocuments      Algorithm 5    sparse.route_return (unsort)
  computeGradients      Algorithm 6    kernels.ops.sigmoid_grad (map body)
                                       + sparse.combine_grads (combiner)
  (reduce shuffle)                     all_to_all(grad sums) + owner
                                       scatter-add
  updateParameters      Algorithm 7    sharded SGD on the owner shard
  hot sharding          §4             hot set replicated, grads psum'd
                                       (see core.hot_sharding)

The distributeParameters / gradient-reduce collectives are pluggable
`DistributionStrategy` objects looked up by name from `repro.api.strategies`
(cfg.distribution: "a2a" | "allgather" | "psum_scatter" | "hier_a2a" |
"compressed_reduce" | "topk_reduce" | "overlap_a2a" | registered
compositions like "hier_a2a+topk" | anything third parties register |
"auto", which asks `repro.api.autotune` for the cheapest strategy under
the analytic per-tier wire-cost model — see `resolve_distribution`).
Strategies see the
mesh's wire tiers — `launch.mesh.tier_axes` factors the axes into the
DCN-crossing outer tier (`pod`) and the ICI inner tier, carried on the
`StrategyContext` — and may keep persistent per-device state (`init_carry`,
e.g. compression error feedback) which lives in `DPMRState.strat`, is
updated by `train_step`, and is checkpointed with the rest of the state.
The optimizer applied in updateParameters and the learning-rate schedule
come from the shared `repro.optim` registries, so the sparse face selects
them exactly like the dense trainer does.
"""
from __future__ import annotations

from collections.abc import Callable
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import DPMRConfig
from repro.core import hot_sharding
from repro.kernels import ops
from repro.optim import optimizers, schedules


class DPMRState(NamedTuple):
    cold: jax.Array       # (F,) f32, sharded over all mesh axes
    hot: jax.Array        # (max_hot,) f32, replicated (Zipf head)
    hot_ids: jax.Array    # (max_hot,) int32 sorted, INT_MAX padded, replicated
    cold_acc: jax.Array   # (F,) adagrad accumulator, sharded like cold
    hot_acc: jax.Array    # (max_hot,) adagrad accumulator, replicated
    step: jax.Array       # () int32
    strat: jax.Array      # (P*L,) f32 per-device strategy carry (L from
    #                       strategy.init_carry; (P,) zeros when stateless),
    #                       sharded over all mesh axes like cold


def _axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def num_shards(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= int(mesh.shape[a])
    return n


def padded_features(cfg: DPMRConfig, mesh) -> int:
    p = num_shards(mesh)
    return -(-cfg.num_features // p) * p


def capacity_for_shards(cfg: DPMRConfig, batch_local: int, p: int,
                        factor: float = 4.0) -> int:
    """`capacity` for an analytic shard count (no mesh required)."""
    n = batch_local * cfg.max_features_per_sample
    mean = max(1, n // p)
    return int(min(n, max(16, -(-int(factor * mean) // 8) * 8)))


def capacity(cfg: DPMRConfig, batch_local: int, mesh,
             factor: float = 4.0) -> int:
    """Per-(src,dst) a2a slots for cold features: factor x the uniform mean."""
    return capacity_for_shards(cfg, batch_local, num_shards(mesh), factor)


def make_strategy_context(cfg: DPMRConfig, mesh, cap: int = 0,
                          kernel_impl: str | None = None):
    """The `StrategyContext` for this (cfg, mesh) geometry: all mesh axes,
    factored into the (outer=DCN, inner=ICI) wire tiers by
    `launch.mesh.tier_axes`. `cap` is the per-(src,dst) a2a capacity
    (batch-size dependent; 0 where only the static geometry matters).
    `kernel_impl` overrides `cfg.kernel_impl` (None = use the config)."""
    # late import: repro.api.strategies imports from repro.core
    from repro.api.strategies import StrategyContext
    from repro.kernels import ops
    from repro.launch.mesh import tier_axes, tier_shards

    outer, inner = tier_axes(mesh)
    po, _ = tier_shards(mesh)
    p = num_shards(mesh)
    impl = ops.normalize_impl(
        cfg.kernel_impl if kernel_impl is None else kernel_impl)
    return StrategyContext(axes=_axes(mesh), num_shards=p,
                           block_size=padded_features(cfg, mesh) // p,
                           capacity=cap, inner_axes=inner, outer_axes=outer,
                           outer_shards=po, topk_frac=cfg.topk_frac,
                           kernel_impl=impl)


_AUTOTUNE_BATCH_LOCAL = 128
#   nominal per-device batch behind cfg.distribution == "auto": the
#   autotuner prices capacity at this fixed size so one (cfg, mesh) pair
#   resolves to ONE strategy — a batch-size-dependent choice could flip
#   between StepFns compilations and invalidate the persistent carry shape


def resolve_distribution(cfg: DPMRConfig, mesh) -> str:
    """The concrete strategy name for this (cfg, mesh): cfg.distribution
    itself, or — when it is the sentinel `"auto"` — the cheapest
    registered strategy under the analytic per-tier wire-cost model
    (`repro.api.autotune.choose_strategy`) on this mesh's geometry."""
    if cfg.distribution != "auto":
        return cfg.distribution
    # late import: repro.api imports this module
    from repro.api import autotune

    ctx = make_strategy_context(
        cfg, mesh, cap=capacity(cfg, _AUTOTUNE_BATCH_LOCAL, mesh))
    return autotune.choose_strategy(ctx)


def strategy_carry_len(cfg: DPMRConfig, mesh) -> int:
    """Per-device length L of the resolved strategy's persistent carry (1
    when the strategy is stateless; the placeholder keeps the state pytree
    shape-stable across strategies at negligible cost)."""
    from repro.api.strategies import get_strategy

    carry = get_strategy(resolve_distribution(cfg, mesh)).init_carry(
        make_strategy_context(cfg, mesh))
    return 1 if carry is None else int(carry.shape[0])


def init_state(cfg: DPMRConfig, mesh, hot_ids=None) -> DPMRState:
    f = padded_features(cfg, mesh)
    axes = _axes(mesh)
    shard = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    cold = jax.device_put(jnp.zeros((f,), jnp.float32), shard)
    cold_acc = jax.device_put(jnp.zeros((f,), jnp.float32), shard)
    hot = jax.device_put(jnp.zeros((cfg.max_hot,), jnp.float32), rep)
    hot_acc = jax.device_put(jnp.zeros((cfg.max_hot,), jnp.float32), rep)
    if hot_ids is None:
        hot_ids = jnp.full((cfg.max_hot,), hot_sharding.INT_MAX, jnp.int32)
    hot_ids = jax.device_put(hot_ids.astype(jnp.int32), rep)
    strat = jax.device_put(
        jnp.zeros((num_shards(mesh) * strategy_carry_len(cfg, mesh),),
                  jnp.float32), shard)
    return DPMRState(cold, hot, hot_ids, cold_acc, hot_acc,
                     jnp.zeros((), jnp.int32), strat)


def optimize(cfg: DPMRConfig, theta, acc, grad, lr):
    """Algorithm 7 step 12: newPara = optimize(para, grad).

    Delegates to the shared sparse-optimizer registry (optim/optimizers.py),
    so the sparse face selects optimizers by name like the dense trainer.
    """
    return optimizers.get_sparse_optimizer(cfg.optimizer).update(
        theta, acc, grad, lr, cfg)


def make_schedule(cfg: DPMRConfig) -> Callable:
    """LR schedule for the sparse face from the shared schedule registry."""
    return schedules.get_schedule_by_name(
        cfg.schedule, cfg.learning_rate,
        warmup_steps=cfg.warmup_steps, total_steps=cfg.total_steps)


# ---------------------------------------------------------------------------
# per-device stage pipeline
# ---------------------------------------------------------------------------


def _device_fwd(cfg, strategy, ctx, kernel_impl,
                cold_loc, hot, hot_ids, ids, vals):
    """Stages distribute+restore: returns (theta (B,K), fwd-state, aux)."""
    flat = ids.reshape(-1)
    hot_slot, is_hot, cold_ids = hot_sharding.split_hot(flat, hot_ids)

    theta_cold, fwd = strategy.distribute(ctx, cold_loc, cold_ids)

    theta_hot = jnp.where(is_hot, hot[jnp.clip(hot_slot, 0)], 0.0)
    theta = (theta_cold + theta_hot).reshape(ids.shape)
    aux = {"hot_slot": hot_slot, "is_hot": is_hot,
           "overflow": fwd["overflow"]}
    return theta, fwd, aux


def _device_grads(cfg, strategy, ctx, kernel_impl,
                  cold_loc, grads_slot, fwd, aux, strat_loc, stateful,
                  accumulating=False):
    """Reduce stages: per-feature sums delivered to owners + hot psum.

    `strat_loc` is this device's slice of the persistent strategy carry;
    stateful strategies receive it as `fwd["carry"]` and return the
    updated value alongside the gradient. `accumulating=True` marks the
    full-batch grad_step path, where the engine DISCARDS the returned
    carry (many grad_steps feed one update) — it reaches the strategy as
    `fwd["accumulate"]` so lossy strategies whose correctness depends on
    the carry advancing (e.g. topk_reduce) can fall back to an exact
    reduce there."""
    gflat = grads_slot.reshape(-1)
    if stateful:
        grad_cold, strat_new = strategy.reduce(
            ctx, cold_loc, gflat,
            {**fwd, "carry": strat_loc, "accumulate": accumulating})
    else:
        grad_cold = strategy.reduce(ctx, cold_loc, gflat, fwd)
        strat_new = strat_loc

    hot_n = jnp.zeros((cfg.max_hot,), jnp.float32)
    ghot = hot_n.at[jnp.where(aux["is_hot"], aux["hot_slot"],
                              cfg.max_hot)].add(
        jnp.where(aux["is_hot"], gflat, 0.0), mode="drop")
    grad_hot = jax.lax.psum(ghot, ctx.axes)
    return grad_cold, grad_hot, strat_new


def _metrics(axes, probs, labels, nll, overflow):
    y = labels.astype(jnp.float32)
    pred = (probs >= 0.5).astype(jnp.float32)
    acc = jnp.mean((pred == y).astype(jnp.float32))
    m = {
        "loss": jax.lax.pmean(jnp.mean(nll), axes),
        "accuracy": jax.lax.pmean(acc, axes),
        "overflow": jax.lax.psum(overflow, axes),
    }
    return m


# ---------------------------------------------------------------------------
# public step builders
# ---------------------------------------------------------------------------


class StepFns(NamedTuple):
    """Typed bundle of compiled DPMR step functions + step geometry.

    Access is attribute-only (`fns.train_step`); the one-release
    deprecated dict-style `fns["train_step"]` has been removed.

    `ctx` is the `StrategyContext` the steps were compiled against —
    feed it to `strategy.bytes_per_device` for the two-tier wire model
    of this exact geometry.

    `train_step` and `apply_update` DONATE their state argument (the
    (F,)-sized table/accumulator buffers alias the outputs instead of
    being copied — `repro.analysis.audit` verifies the aliasing survives
    lowering). Treat the passed-in state as consumed; snapshot with
    `jax.tree.map(jnp.copy, state)` first if you need the old value.
    `grad_step` and `predict` do not donate.
    """

    train_step: Callable     # (state, batch) -> (state, metrics)
    grad_step: Callable      # (state, batch) -> (grad_cold, grad_hot, metrics)
    apply_update: Callable   # (state, grad_cold, grad_hot, lr) -> state
    predict: Callable        # (state, batch) -> probs
    capacity: int            # per-(src,dst) a2a slots
    block_size: int          # feature-table rows per device
    num_shards: int          # P
    strategy: str = "a2a"    # RESOLVED distribution-strategy name (a
    #                          concrete registry entry, never "auto")
    ctx: object = None       # StrategyContext of this compilation


def make_step_fns(cfg: DPMRConfig, mesh, batch_size: int,
                  kernel_impl: str | None = None,
                  cap_factor: float = 4.0) -> StepFns:
    """Build jitted StepFns(train_step, grad_step, apply_update, predict)
    for a GLOBAL batch of `batch_size` samples (sharded over all mesh
    axes).

    `kernel_impl` picks the hot-path lowering ("xla" | "pallas" |
    "pallas_interpret", see repro.kernels.ops.KERNEL_IMPLS); None defers
    to `cfg.kernel_impl`. It reaches the strategies through
    `StrategyContext.kernel_impl` and the map body through
    `ops.sigmoid_grad`, never the collectives — the wire layout is
    impl-independent by construction."""
    # late import: repro.api.engine imports this module
    from repro.api.strategies import get_strategy

    axes = _axes(mesh)
    p = num_shards(mesh)
    f = padded_features(cfg, mesh)
    block = f // p
    assert batch_size % p == 0, (batch_size, p)
    cap = capacity(cfg, batch_size // p, mesh, cap_factor)
    dist = resolve_distribution(cfg, mesh)
    strategy = get_strategy(dist)
    kernel_impl = ops.normalize_impl(
        cfg.kernel_impl if kernel_impl is None else kernel_impl)
    ctx = make_strategy_context(cfg, mesh, cap, kernel_impl=kernel_impl)
    stateful = strategy.init_carry(ctx) is not None
    sched = make_schedule(cfg)

    def _fwd_grads(cold_loc, hot, hot_ids, strat_loc, ids, vals, labels,
                   accumulating=False):
        theta, fwd, aux = _device_fwd(
            cfg, strategy, ctx, kernel_impl,
            cold_loc, hot, hot_ids, ids, vals)
        grads_slot, probs, nll = ops.sigmoid_grad(
            vals, theta, labels, impl=kernel_impl)
        if cfg.grad_scale == "mean":
            grads_slot = grads_slot / float(batch_size)
        grad_cold, grad_hot, strat_new = _device_grads(
            cfg, strategy, ctx, kernel_impl,
            cold_loc, grads_slot, fwd, aux, strat_loc, stateful,
            accumulating=accumulating)
        return grad_cold, grad_hot, strat_new, _metrics(
            axes, probs, labels, nll, aux["overflow"])

    def train_dev(cold_loc, hot, hot_ids, cold_acc, hot_acc, step,
                  strat_loc, ids, vals, labels):
        grad_cold, grad_hot, strat_new, m = _fwd_grads(
            cold_loc, hot, hot_ids, strat_loc, ids, vals, labels)
        lr = sched(step)
        cold_new, cold_acc = optimize(cfg, cold_loc, cold_acc, grad_cold, lr)
        hot_new, hot_acc = optimize(cfg, hot, hot_acc, grad_hot, lr)
        return (cold_new, hot_new, hot_ids, cold_acc, hot_acc, step + 1,
                strat_new, m)

    def grad_dev(cold_loc, hot, hot_ids, strat_loc, ids, vals, labels):
        # the carry is read-only here: full-batch fit() accumulates raw
        # gradients across many grad_steps before one update, so per-batch
        # carry mutation would double-count; error feedback advances
        # through train_step (the SGD path) only. accumulating=True tells
        # the strategy (fwd["accumulate"]) so ones that MUST advance the
        # carry to stay correct can take an exact path instead.
        grad_cold, grad_hot, _, m = _fwd_grads(
            cold_loc, hot, hot_ids, strat_loc, ids, vals, labels,
            accumulating=True)
        return grad_cold, grad_hot, m

    def predict_dev(cold_loc, hot, hot_ids, ids, vals):
        theta, _, _ = _device_fwd(cfg, strategy, ctx, kernel_impl,
                                  cold_loc, hot, hot_ids, ids, vals)
        logits = jnp.sum(vals * theta, axis=-1)
        return jax.nn.sigmoid(logits)

    shard = P(axes)
    rep = P()
    smap = functools.partial(compat.shard_map, mesh=mesh, check_vma=False)

    train_m = smap(train_dev,
                   in_specs=(shard, rep, rep, shard, rep, rep, shard,
                             shard, shard, shard),
                   out_specs=(shard, rep, rep, shard, rep, rep, shard, rep))
    grad_m = smap(grad_dev,
                  in_specs=(shard, rep, rep, shard, shard, shard, shard),
                  out_specs=(shard, rep, rep))
    pred_m = smap(predict_dev,
                  in_specs=(shard, rep, rep, shard, shard),
                  out_specs=shard)

    # the consumed state is DONATED in both updating steps: the (F,)-sized
    # table/accumulator buffers alias their outputs instead of being copied
    # (the analysis auditor checks the aliasing survives lowering). Callers
    # must treat the passed-in state as dead — engine.train_step/fit do.
    # grad_step/predict deliberately do NOT donate: fit() reuses one state
    # across many grad_steps, and predict never updates it.
    @functools.partial(jax.jit, donate_argnums=(0,))
    def train_step(state: DPMRState, batch):
        cold, hot, hot_ids, cold_acc, hot_acc, step, strat, m = train_m(
            state.cold, state.hot, state.hot_ids, state.cold_acc,
            state.hot_acc, state.step, state.strat,
            batch["ids"], batch["vals"], batch["labels"])
        return DPMRState(cold, hot, hot_ids, cold_acc, hot_acc, step,
                         strat), m

    @jax.jit
    def grad_step(state: DPMRState, batch):
        return grad_m(state.cold, state.hot, state.hot_ids, state.strat,
                      batch["ids"], batch["vals"], batch["labels"])

    @functools.partial(jax.jit, donate_argnums=(0,))
    def apply_update(state: DPMRState, grad_cold, grad_hot, lr: float):
        cold, cold_acc = optimize(cfg, state.cold, state.cold_acc,
                                  grad_cold, lr)
        hot, hot_acc = optimize(cfg, state.hot, state.hot_acc, grad_hot, lr)
        return DPMRState(cold, hot, state.hot_ids, cold_acc, hot_acc,
                         state.step + 1, state.strat)

    @jax.jit
    def predict(state: DPMRState, batch):
        return pred_m(state.cold, state.hot, state.hot_ids,
                      batch["ids"], batch["vals"])

    return StepFns(train_step=train_step, grad_step=grad_step,
                   apply_update=apply_update, predict=predict,
                   capacity=cap, block_size=block, num_shards=p,
                   strategy=dist, ctx=ctx)
