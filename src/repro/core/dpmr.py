"""Distributed Parameter Map-Reduce — the paper's engine on TPU collectives.

Algorithm 1/8 of the paper as a shard_map program over ALL mesh axes (every
device is a DPMR node holding both a sample shard and a parameter shard,
exactly the paper's HDFS co-location):

  stage                 paper          here (per train step)
  -----                 -----          ----
  initParameters        Algorithm 2    init_state (zeros; hot stats external)
  invertDocuments       Algorithm 3    sparse.route_build (sort-by-feature)
  distributeParameters  Algorithm 4    all_to_all(requests) + owner lookup
                                       + all_to_all(responses)
  restoreDocuments      Algorithm 5    sparse.route_return (unsort)
  computeGradients      Algorithm 6    kernels.ops.sigmoid_grad (map body)
                                       + sparse.combine_grads (combiner)
  (reduce shuffle)                     all_to_all(grad sums) + owner
                                       scatter-add
  updateParameters      Algorithm 7    sharded SGD on the owner shard
  hot sharding          §4             hot set replicated, grads psum'd
                                       (see core.hot_sharding)

Two distribution strategies (cfg.distribution):
  "a2a"       the DPMR shuffle: bytes/device ~ 3 * P * cap * 4 per step,
              independent of feature-space size F.
  "allgather" the parameter-server-free strawman (gather the whole table):
              bytes/device ~ F * 4. Used as the comparison baseline in the
              benchmarks — the paper's speedup claim is exactly that the
              shuffle beats shipping the table.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import DPMRConfig
from repro.core import hot_sharding, sparse
from repro.kernels import ops


class DPMRState(NamedTuple):
    cold: jax.Array       # (F,) f32, sharded over all mesh axes
    hot: jax.Array        # (max_hot,) f32, replicated (Zipf head)
    hot_ids: jax.Array    # (max_hot,) int32 sorted, INT_MAX padded, replicated
    cold_acc: jax.Array   # (F,) adagrad accumulator, sharded like cold
    hot_acc: jax.Array    # (max_hot,) adagrad accumulator, replicated
    step: jax.Array       # () int32


def _axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def num_shards(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= int(mesh.shape[a])
    return n


def padded_features(cfg: DPMRConfig, mesh) -> int:
    p = num_shards(mesh)
    return -(-cfg.num_features // p) * p


def capacity(cfg: DPMRConfig, batch_local: int, mesh,
             factor: float = 4.0) -> int:
    """Per-(src,dst) a2a slots for cold features: factor x the uniform mean."""
    p = num_shards(mesh)
    n = batch_local * cfg.max_features_per_sample
    mean = max(1, n // p)
    return int(min(n, max(16, -(-int(factor * mean) // 8) * 8)))


def init_state(cfg: DPMRConfig, mesh, hot_ids=None) -> DPMRState:
    f = padded_features(cfg, mesh)
    axes = _axes(mesh)
    shard = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    cold = jax.device_put(jnp.zeros((f,), jnp.float32), shard)
    cold_acc = jax.device_put(jnp.zeros((f,), jnp.float32), shard)
    hot = jax.device_put(jnp.zeros((cfg.max_hot,), jnp.float32), rep)
    hot_acc = jax.device_put(jnp.zeros((cfg.max_hot,), jnp.float32), rep)
    if hot_ids is None:
        hot_ids = jnp.full((cfg.max_hot,), hot_sharding.INT_MAX, jnp.int32)
    hot_ids = jax.device_put(hot_ids.astype(jnp.int32), rep)
    return DPMRState(cold, hot, hot_ids, cold_acc, hot_acc,
                     jnp.zeros((), jnp.int32))


def optimize(cfg: DPMRConfig, theta, acc, grad, lr):
    """Algorithm 7 step 12: newPara = optimize(para, grad)."""
    if cfg.optimizer == "adagrad":
        acc = acc + grad * grad
        step = grad * jax.lax.rsqrt(acc + cfg.adagrad_eps)
        return theta - lr * step, acc
    return theta - lr * grad, acc


# ---------------------------------------------------------------------------
# per-device stage pipeline
# ---------------------------------------------------------------------------


def _device_fwd(cfg, axes, p, block, cap, kernel_impl,
                cold_loc, hot, hot_ids, ids, vals):
    """Stages distribute+restore: returns (theta (B,K), routing, aux)."""
    me = jax.lax.axis_index(axes)
    base = me * block
    flat = ids.reshape(-1)
    hot_slot, is_hot, cold_ids = hot_sharding.split_hot(flat, hot_ids)

    if cfg.distribution == "allgather":
        table = jax.lax.all_gather(cold_loc, axes, tiled=True)       # (F,)
        theta_cold = jnp.where(cold_ids >= 0,
                               table[jnp.clip(cold_ids, 0)], 0.0)
        routing = None
        overflow = jnp.zeros((), jnp.int32)
    else:
        routing = sparse.route_build(cold_ids, p, block, cap)
        req_recv = jax.lax.all_to_all(routing.req_ids, axes, 0, 0, tiled=True)
        resp = sparse.owner_apply(req_recv, cold_loc, base)
        resp_back = jax.lax.all_to_all(resp, axes, 0, 0, tiled=True)
        theta_cold = sparse.route_return(routing, resp_back)
        req_recv_saved = req_recv
        overflow = routing.overflow

    theta_hot = jnp.where(is_hot, hot[jnp.clip(hot_slot, 0)], 0.0)
    theta = (theta_cold + theta_hot).reshape(ids.shape)
    aux = {
        "hot_slot": hot_slot, "is_hot": is_hot, "cold_ids": cold_ids,
        "overflow": overflow,
        "req_recv": None if routing is None else req_recv_saved,
    }
    return theta, routing, aux


def _device_grads(cfg, axes, p, block, cap, kernel_impl,
                  cold_loc, grads_slot, routing, aux):
    """Reduce stages: per-feature sums delivered to owners + hot psum."""
    me = jax.lax.axis_index(axes)
    base = me * block
    gflat = grads_slot.reshape(-1)

    if cfg.distribution == "allgather":
        f = cold_loc.shape[0] * p
        gfull = jnp.zeros((f,), jnp.float32).at[
            jnp.where(aux["cold_ids"] >= 0, aux["cold_ids"], f)
        ].add(jnp.where(aux["cold_ids"] >= 0, gflat, 0.0), mode="drop")
        grad_cold = jax.lax.psum_scatter(gfull, axes, scatter_dimension=0,
                                         tiled=True)
    else:
        send = sparse.combine_grads(routing, gflat)
        recv = jax.lax.all_to_all(send, axes, 0, 0, tiled=True)
        grad_cold = sparse.owner_accumulate(
            aux["req_recv"], recv, jnp.zeros_like(cold_loc), base)

    hot_n = jnp.zeros((cfg.max_hot,), jnp.float32)
    ghot = hot_n.at[jnp.where(aux["is_hot"], aux["hot_slot"],
                              cfg.max_hot)].add(
        jnp.where(aux["is_hot"], gflat, 0.0), mode="drop")
    grad_hot = jax.lax.psum(ghot, axes)
    return grad_cold, grad_hot


def _metrics(axes, probs, labels, nll, overflow):
    y = labels.astype(jnp.float32)
    pred = (probs >= 0.5).astype(jnp.float32)
    acc = jnp.mean((pred == y).astype(jnp.float32))
    m = {
        "loss": jax.lax.pmean(jnp.mean(nll), axes),
        "accuracy": jax.lax.pmean(acc, axes),
        "overflow": jax.lax.psum(overflow, axes),
    }
    return m


# ---------------------------------------------------------------------------
# public step builders
# ---------------------------------------------------------------------------


def make_step_fns(cfg: DPMRConfig, mesh, batch_size: int,
                  kernel_impl: str = "jnp", cap_factor: float = 4.0):
    """Build jitted {train_step, grad_step, apply_update, predict} for a
    GLOBAL batch of `batch_size` samples (sharded over all mesh axes)."""
    axes = _axes(mesh)
    p = num_shards(mesh)
    f = padded_features(cfg, mesh)
    block = f // p
    assert batch_size % p == 0, (batch_size, p)
    cap = capacity(cfg, batch_size // p, mesh, cap_factor)

    def _fwd_grads(cold_loc, hot, hot_ids, ids, vals, labels):
        theta, routing, aux = _device_fwd(
            cfg, axes, p, block, cap, kernel_impl,
            cold_loc, hot, hot_ids, ids, vals)
        grads_slot, probs, nll = ops.sigmoid_grad(
            vals, theta, labels, impl=kernel_impl)
        if cfg.grad_scale == "mean":
            grads_slot = grads_slot / float(batch_size)
        grad_cold, grad_hot = _device_grads(
            cfg, axes, p, block, cap, kernel_impl,
            cold_loc, grads_slot, routing, aux)
        return grad_cold, grad_hot, _metrics(axes, probs, labels, nll,
                                             aux["overflow"])

    def train_dev(cold_loc, hot, hot_ids, cold_acc, hot_acc, step,
                  ids, vals, labels):
        grad_cold, grad_hot, m = _fwd_grads(cold_loc, hot, hot_ids,
                                            ids, vals, labels)
        lr = cfg.learning_rate
        cold_new, cold_acc = optimize(cfg, cold_loc, cold_acc, grad_cold, lr)
        hot_new, hot_acc = optimize(cfg, hot, hot_acc, grad_hot, lr)
        return cold_new, hot_new, hot_ids, cold_acc, hot_acc, step + 1, m

    def grad_dev(cold_loc, hot, hot_ids, ids, vals, labels):
        return _fwd_grads(cold_loc, hot, hot_ids, ids, vals, labels)

    def predict_dev(cold_loc, hot, hot_ids, ids, vals):
        theta, _, _ = _device_fwd(cfg, axes, p, block, cap, kernel_impl,
                                  cold_loc, hot, hot_ids, ids, vals)
        logits = jnp.sum(vals * theta, axis=-1)
        return jax.nn.sigmoid(logits)

    shard = P(axes)
    rep = P()
    smap = functools.partial(jax.shard_map, mesh=mesh, check_vma=False)

    train_m = smap(train_dev,
                   in_specs=(shard, rep, rep, shard, rep, rep,
                             shard, shard, shard),
                   out_specs=(shard, rep, rep, shard, rep, rep, rep))
    grad_m = smap(grad_dev,
                  in_specs=(shard, rep, rep, shard, shard, shard),
                  out_specs=(shard, rep, rep))
    pred_m = smap(predict_dev,
                  in_specs=(shard, rep, rep, shard, shard),
                  out_specs=shard)

    @jax.jit
    def train_step(state: DPMRState, batch):
        cold, hot, hot_ids, cold_acc, hot_acc, step, m = train_m(
            state.cold, state.hot, state.hot_ids, state.cold_acc,
            state.hot_acc, state.step,
            batch["ids"], batch["vals"], batch["labels"])
        return DPMRState(cold, hot, hot_ids, cold_acc, hot_acc, step), m

    @jax.jit
    def grad_step(state: DPMRState, batch):
        return grad_m(state.cold, state.hot, state.hot_ids,
                      batch["ids"], batch["vals"], batch["labels"])

    @jax.jit
    def apply_update(state: DPMRState, grad_cold, grad_hot, lr: float):
        cold, cold_acc = optimize(cfg, state.cold, state.cold_acc,
                                  grad_cold, lr)
        hot, hot_acc = optimize(cfg, state.hot, state.hot_acc, grad_hot, lr)
        return DPMRState(cold, hot, state.hot_ids, cold_acc, hot_acc,
                         state.step + 1)

    @jax.jit
    def predict(state: DPMRState, batch):
        return pred_m(state.cold, state.hot, state.hot_ids,
                      batch["ids"], batch["vals"])

    return {"train_step": train_step, "grad_step": grad_step,
            "apply_update": apply_update, "predict": predict,
            "capacity": cap, "block_size": block, "num_shards": p}
