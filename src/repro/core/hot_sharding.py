"""Zipf hot-feature handling (paper §4, adapted).

On Hadoop, a head feature's `feature -> sample` line spans ~20 HDFS blocks
and serializes one reducer; the paper splits it into N sub-features. In SPMD
the same skew shows up as per-owner request-buffer overflow (the a2a
capacity). The adaptation: features above a frequency threshold are
REPLICATED on every device (their parameters travel with the program, their
gradients reduce over the full mesh with one psum), and only the Zipf tail
goes through the a2a routing — which is near-uniform by hashing, so a small
capacity factor suffices. `select_hot` is the initParameters-time frequency
statistic the paper passes to its sharding mappers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max


def feature_counts(ids: jax.Array, num_features: int) -> jax.Array:
    """Histogram of feature occurrences. ids: any shape, -1 = padding."""
    flat = ids.reshape(-1)
    return jnp.zeros((num_features,), jnp.int32).at[
        jnp.where(flat >= 0, flat, num_features)
    ].add(1, mode="drop")


def select_hot(counts: jax.Array, threshold: float, max_hot: int
               ) -> jax.Array:
    """Pick features with frequency above `threshold`, capped at max_hot.

    Returns (max_hot,) int32 sorted ascending, padded with INT_MAX so
    searchsorted stays valid.
    """
    total = jnp.maximum(jnp.sum(counts), 1)
    freq = counts.astype(jnp.float32) / total.astype(jnp.float32)
    eligible = freq >= threshold
    score = jnp.where(eligible, counts, -1)
    top_counts, top_ids = jax.lax.top_k(score, max_hot)
    ids = jnp.where(top_counts > 0, top_ids, INT_MAX)
    return jnp.sort(ids).astype(jnp.int32)


def split_hot(ids_flat: jax.Array, hot_ids: jax.Array
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partition flat ids into hot/cold.

    Returns (hot_slot (n,) int32 index into hot_ids or -1,
             is_hot (n,) bool,
             cold_ids (n,) int32 with hot & padding replaced by -1).
    """
    pos = jnp.searchsorted(hot_ids, ids_flat)
    pos_c = jnp.clip(pos, 0, hot_ids.shape[0] - 1)
    is_hot = (hot_ids[pos_c] == ids_flat) & (ids_flat >= 0)
    hot_slot = jnp.where(is_hot, pos_c, -1)
    cold_ids = jnp.where(is_hot | (ids_flat < 0), -1, ids_flat)
    return hot_slot, is_hot, cold_ids


def load_imbalance(ids_flat: jax.Array, num_shards: int, block_size: int
                   ) -> jax.Array:
    """max/mean owner load for this device's cold ids (skew diagnostic)."""
    owner = jnp.where(ids_flat >= 0, ids_flat // block_size, num_shards)
    counts = jnp.zeros((num_shards,), jnp.int32).at[owner].add(
        1, mode="drop")
    mean = jnp.maximum(jnp.mean(counts.astype(jnp.float32)), 1e-6)
    return jnp.max(counts).astype(jnp.float32) / mean
