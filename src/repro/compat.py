"""Version-portable wrappers over the handful of jax APIs that moved.

The repo targets the current jax surface (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``); the container may pin an older release where those
live under ``jax.experimental.shard_map`` / don't exist yet. Every module that
needs one of these goes through this file so the rest of the codebase is
written once, against the new names.
"""
from __future__ import annotations

from collections.abc import Sequence
import contextlib

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """jax.make_mesh with Auto axis_types where the kwarg exists."""
    if _HAS_AXIS_TYPE:
        auto = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=auto)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def set_mesh(mesh):
    """Context manager activating `mesh` (jax.set_mesh on new jax)."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    if mesh is None:
        return contextlib.nullcontext()
    # jax.sharding.Mesh has been a context manager since the pjit era
    return mesh


def get_abstract_mesh():
    """The mesh of the ambient context (jax.sharding.get_abstract_mesh on
    new jax; the `with mesh:` physical mesh on old). May be empty."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def axis_size(axis_name):
    """Size of a mapped mesh axis inside shard_map (jax.lax.axis_size on
    new jax; a psum of ones on old, which folds to the same constant)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: set[str] | None = None, check_vma: bool = False):
    """jax.shard_map(...) on new jax; experimental.shard_map on old.

    `axis_names` follows the NEW convention: the set of mesh axes that are
    manual inside `f` (None = all of them). On old jax this is translated to
    the `auto` complement set.
    """
    if _HAS_NEW_SHARD_MAP:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental import shard_map as _sm

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm.shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=check_vma, auto=auto)
