"""Deterministic, resumable synthetic LM data pipeline.

Every batch is a pure function of (seed, step): resuming a failed run at
step k reproduces exactly the batches a healthy run would have seen (the
iterator state is just the integer step stored in the checkpoint). Documents
are Markov-chain token streams packed to seq_len with next-token labels —
enough structure for loss to move in the integration tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class LMDataset:
    """Seekable synthetic dataset: `batch(step)` is pure."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse-ish Markov transition structure (each token -> 8 likely next)
        self._next = rng.integers(0, v, size=(v, 8)).astype(np.int32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choice = rng.integers(0, 8, size=(b, s))
        noise = rng.random((b, s)) < 0.1
        rand_tok = rng.integers(0, cfg.vocab_size, size=(b, s))
        for t in range(s):
            nxt = self._next[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    # The one-release deprecated `iterate(start_step)` generator has been
    # REMOVED — use get_source("lm_markov", vocab_size=V, seq_len=S,
    # batch_size=B) behind a repro.data.ShardedLoader and seek its cursor
    # (bit-identical batches; migration note in CHANGES.md).


def encdec_batch(ds: LMDataset, step: int, d_model: int) -> dict:
    """Whisper-style batch: stub frame embeddings + target tokens."""
    base = ds.batch(step)
    b, s = base["tokens"].shape
    rng = np.random.default_rng(np.random.SeedSequence([ds.cfg.seed, step, 7]))
    frames = rng.normal(0, 1, size=(b, s, d_model)).astype(np.float32)
    return {"frames": frames, **base}
