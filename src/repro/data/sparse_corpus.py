"""Synthetic Zipf sparse-LR corpus — the paper's data regime, scaled down.

The paper trains on ~20B ad-log samples over ~50B features with a Zipf
frequency profile and a ~3:1 class imbalance (Fig. 1). We generate the same
statistical shape: feature ids ~ Zipf(alpha) over a hashed space, a sparse
ground-truth weight vector, labels ~ Bernoulli(sigmoid(theta* . x + b)) with
b tuned to the target positive rate.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    num_features: int = 1 << 20
    features_per_sample: int = 64    # K (padded CSR width)
    min_features: int = 8
    zipf_alpha: float = 1.2
    signal_features: int = 4096      # features with non-zero true weight
    positive_ratio: float = 0.75     # paper: +1 : -1 roughly 3 : 1
    seed: int = 0


def _zipf_ids(rng: np.random.Generator, spec: CorpusSpec, n: int
              ) -> np.ndarray:
    """Zipf-distributed feature ids in [0, F)."""
    raw = rng.zipf(spec.zipf_alpha, size=n).astype(np.int64)
    # map the unbounded Zipf variate into [0, F) preserving rank order, then
    # hash to decorrelate id and frequency rank (ids are arbitrary strings in
    # the paper; ownership must not align with frequency)
    ranked = (raw - 1) % spec.num_features
    h = (ranked * np.int64(2654435761)) % np.int64(spec.num_features)
    return h.astype(np.int32)


def true_weights(spec: CorpusSpec) -> tuple[np.ndarray, np.ndarray]:
    """(ids, weights) of the sparse ground truth.

    Signal lives on the most FREQUENT features (the Zipf head) — as in real
    CTR logs, where informative features are the common ones; this also makes
    the paper's hot-feature sharding matter for model quality, not just load.
    """
    rng = np.random.default_rng(spec.seed + 7)
    ranks = np.arange(spec.signal_features, dtype=np.int64)
    ids = ((ranks % spec.num_features) * np.int64(2654435761)
           % np.int64(spec.num_features)).astype(np.int32)
    ids = np.unique(ids)
    w = rng.normal(0.0, 2.0, size=ids.shape[0]).astype(np.float32)
    return ids, w


def batch_seed(spec: CorpusSpec, index: int) -> int:
    """THE per-index seeding rule of the Zipf corpus. Single definition —
    `ZipfSparseSource.batch(i)` and the legacy `batches` generator both use
    it, and checkpoint resume-exactness depends on it never diverging."""
    return spec.seed * 100003 + index


def make_batch(spec: CorpusSpec, batch_size: int, seed: int):
    """One padded-CSR batch: dict(ids (B,K), vals (B,K), labels (B,))."""
    rng = np.random.default_rng(seed)
    k = spec.features_per_sample
    ids = _zipf_ids(rng, spec, batch_size * k).reshape(batch_size, k)
    # deduplicate within a row (count repeats as value weight)
    vals = np.ones((batch_size, k), np.float32)
    row_sorted = np.sort(ids, axis=1)
    # variable sample length: mask a suffix
    lens = rng.integers(spec.min_features, k + 1, size=batch_size)
    mask = np.arange(k)[None, :] < lens[:, None]
    ids = np.where(mask, ids, -1).astype(np.int32)
    vals = np.where(mask, vals, 0.0).astype(np.float32)
    # counts normalized like tf-idf-ish scaling to keep logits bounded
    vals = vals / np.sqrt(np.maximum(lens, 1))[:, None].astype(np.float32)

    tid, tw = true_weights(spec)
    wmap = np.zeros(spec.num_features, np.float32)
    wmap[tid] = tw
    logits = (wmap[np.clip(ids, 0, None)] * vals * (ids >= 0)).sum(axis=1)
    # bias for the target class imbalance
    bias = np.log(spec.positive_ratio / (1 - spec.positive_ratio))
    p = 1.0 / (1.0 + np.exp(-(logits + bias)))
    labels = (rng.random(batch_size) < p).astype(np.int32)
    return {"ids": ids, "vals": vals, "labels": labels}

# The one-release deprecated `batches(spec, bs, n, start)` generator has
# been REMOVED — use get_source("zipf_sparse", spec=spec, batch_size=B,
# num_batches=n, start=k) behind a repro.data.ShardedLoader (bit-identical
# batches; migration note in CHANGES.md).
