"""Unified data plane: `DataSource` registry + prefetching `ShardedLoader`.

    from repro.data import (Cursor, DataSource, ShardedLoader, get_source,
                            list_sources, register_source, write_file_corpus)

Sources are deterministic, seekable batch stores selected by name
(`zipf_sparse`, `lm_markov`, `file_sparse`, user-registered); the loader
fronts one with per-host shard ownership (chunk-aligned file ranges via
the `owned_shards` seam / `ShardAssignment`, stride interleaving for
synthetic sources), mesh-divisibility conformance, background prefetch,
and an explicit resumable `Cursor`. `DPMREngine.fit/fit_sgd/evaluate`
accept a loader (or a source name + spec) directly.

The legacy generators (`sparse_corpus.batches`, `pipeline.LMDataset.iterate`)
are thin deprecation shims over the same batch functions.
"""
from repro.data.loader import Cursor, ShardedLoader
from repro.data.ownership import ShardAssignment, reassign_state
from repro.data.sources import (
    DataSource,
    FileSparseSource,
    LMMarkovSource,
    ZipfSparseSource,
    get_source,
    list_sources,
    register_source,
    write_file_corpus,
)

__all__ = [
    "Cursor", "DataSource", "FileSparseSource", "LMMarkovSource",
    "ShardAssignment", "ShardedLoader", "ZipfSparseSource", "get_source",
    "list_sources", "reassign_state", "register_source",
    "write_file_corpus",
]
