"""`DataSource` — the data-plane analogue of the strategy registry.

The paper's premise is that training samples live in a distributed file
system and every iteration streams sample shards through map tasks. This
module makes that input face a first-class, pluggable component, mirroring
the PR 1 compute-face design (`repro.api.strategies`): a `DataSource` is a
seekable, deterministic batch store — `batch(index)` is a pure function of
the index — and sources are constructed by name through a registry:

    from repro.data import get_source, list_sources, register_source

    src = get_source("zipf_sparse", batch_size=512, num_batches=8,
                     num_features=1 << 14)
    b = src.batch(3)            # same dict every time it is asked for

Built-ins:

  zipf_sparse   synthetic Zipf CTR corpus (wraps `sparse_corpus.make_batch`)
  lm_markov     synthetic Markov LM stream (wraps `pipeline.LMDataset`),
                optionally with encoder frames for encdec families
  file_sparse   packed-CSR chunk files on disk — the paper's HDFS sample
                shards. `write_file_corpus` materializes any sparse source
                into sharded .npz chunks + a manifest; `FileSparseSource`
                reads them back with a one-shard read cache.

Purity of `batch(index)` is the load-bearing property: resumable cursors,
host sharding, and prefetching in `repro.data.loader` all assume that
re-asking for an index reproduces the batch bit-for-bit.

Third parties extend the seam with either

    @register_source("my_source")
    class MySource(DataSource): ...

or `register_source("name", factory)` where `factory(**spec)` builds one.
"""
from __future__ import annotations

from collections.abc import Callable, Iterator
import json
import os
import threading

import numpy as np

from repro.data import sparse_corpus
from repro.data.ownership import ShardAssignment
from repro.data.pipeline import LMDataConfig, LMDataset, encdec_batch


class DataSource:
    """A deterministic, seekable batch store.

    Attributes
    ----------
    name:         registered name (set for built-ins; informational)
    batch_size:   samples per batch (axis 0 of every leaf)
    num_batches:  batches per epoch, or None for an unbounded stream
    """

    name: str = "base"
    batch_size: int = 0
    num_batches: int | None = None

    def batch(self, index: int) -> dict[str, np.ndarray]:
        """The batch at `index` — MUST be a pure function of the index."""
        raise NotImplementedError

    def iter_batches(self, start: int = 0,
                     limit: int | None = None) -> Iterator[dict]:
        """Plain host-side iteration (no sharding, no prefetch)."""
        i = start
        while limit is None or i < start + limit:
            if self.num_batches is not None and i >= self.num_batches:
                return
            yield self.batch(i)
            i += 1

    def owned_shards(self, host: int, num_hosts: int
                     ) -> ShardAssignment | None:
        """The global `ShardAssignment` dividing this corpus over
        `num_hosts` hosts (`host` is validated against it).

        File-backed sources return chunk-aligned contiguous ranges, so a
        host opens only its own chunk files; synthetic sources have no
        files to own and declare the `stride` interleaving (host h reads
        batches h, h+H, ...). Unbounded streams return None — ownership
        needs a bounded corpus to divide."""
        if self.num_batches is None:
            return None
        a = ShardAssignment.strided(self.num_batches, num_hosts)
        a._check_host(host)
        return a

    def _check_index(self, index: int) -> None:
        if index < 0 or (self.num_batches is not None
                         and index >= self.num_batches):
            raise IndexError(
                f"batch index {index} out of range for {self.name!r} "
                f"source with num_batches={self.num_batches}")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


_REGISTRY: dict[str, Callable[..., DataSource]] = {}


def register_source(name: str, factory: Callable[..., DataSource] = None):
    """Register a source factory (`factory(**spec) -> DataSource`), or use
    as a class decorator:

        @register_source("mine")
        class Mine(DataSource): ...
    """
    if factory is not None:
        _REGISTRY[name] = factory
        return factory

    def _decorate(cls):
        _REGISTRY[name] = cls
        return cls

    return _decorate


def get_source(name: str, **spec) -> DataSource:
    """Instantiate a registered source from its name + spec kwargs."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown data source {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None
    return factory(**spec)


def list_sources() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# built-in: synthetic Zipf sparse-LR corpus
# ---------------------------------------------------------------------------


@register_source("zipf_sparse")
class ZipfSparseSource(DataSource):
    """Synthetic Zipf CTR corpus; `batch(i)` == the i-th batch the legacy
    `sparse_corpus.batches` generator produced (same seeding scheme), so
    migrated call sites see bit-identical data.

    `start` offsets the index space — the idiom for carving a held-out test
    range out of the same stream (`start=50, num_batches=4` == old
    `batches(spec, bs, 54, start=50)`).
    """

    name = "zipf_sparse"

    def __init__(self, spec: sparse_corpus.CorpusSpec = None, *,
                 batch_size: int = 512, num_batches: int | None = None,
                 start: int = 0, **spec_kw):
        if spec is not None and spec_kw:
            raise TypeError("pass either spec= or CorpusSpec fields, not both")
        self.spec = spec if spec is not None \
            else sparse_corpus.CorpusSpec(**spec_kw)
        self.batch_size = int(batch_size)
        self.num_batches = None if num_batches is None else int(num_batches)
        self.start = int(start)

    def batch(self, index: int) -> dict[str, np.ndarray]:
        self._check_index(index)
        return sparse_corpus.make_batch(
            self.spec, self.batch_size,
            seed=sparse_corpus.batch_seed(self.spec, self.start + index))


# ---------------------------------------------------------------------------
# built-in: synthetic Markov LM stream (dense face)
# ---------------------------------------------------------------------------


@register_source("lm_markov")
class LMMarkovSource(DataSource):
    """Markov-chain LM batches; `batch(i)` == `LMDataset.batch(i)` (and, with
    `encdec_d_model` set, `pipeline.encdec_batch` — whisper-style frames)."""

    name = "lm_markov"

    def __init__(self, *, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, num_batches: int | None = None,
                 encdec_d_model: int = 0):
        self._ds = LMDataset(LMDataConfig(vocab_size, seq_len, batch_size,
                                          seed=seed))
        self.batch_size = int(batch_size)
        self.num_batches = None if num_batches is None else int(num_batches)
        self.encdec_d_model = int(encdec_d_model)

    def batch(self, index: int) -> dict[str, np.ndarray]:
        self._check_index(index)
        if self.encdec_d_model:
            return encdec_batch(self._ds, index, self.encdec_d_model)
        return self._ds.batch(index)


# ---------------------------------------------------------------------------
# built-in: sharded packed-CSR chunk files on disk (the paper's HDFS shards)
# ---------------------------------------------------------------------------

_MANIFEST = "manifest.json"
_FORMAT = "dpmr_file_sparse_v1"


def _shard_path(directory: str, shard: int) -> str:
    return os.path.join(directory, f"chunk_{shard:05d}.npz")


def write_file_corpus(directory: str, source: DataSource,
                      num_batches: int | None = None,
                      batches_per_chunk: int = 8) -> dict:
    """Materialize `source` into sharded chunk files under `directory`.

    Each chunk file holds `batches_per_chunk` consecutive batches with every
    leaf stacked along a new axis 0 (so a chunk of padded-CSR batches is
    ids (n,B,K) / vals (n,B,K) / labels (n,B)); `manifest.json` records the
    geometry. Returns the manifest dict.
    """
    n = num_batches if num_batches is not None else source.num_batches
    if n is None:
        raise ValueError("write_file_corpus needs num_batches for an "
                         "unbounded source")
    os.makedirs(directory, exist_ok=True)
    keys = None
    num_chunks = -(-n // batches_per_chunk)
    for c in range(num_chunks):
        lo, hi = c * batches_per_chunk, min(n, (c + 1) * batches_per_chunk)
        chunk = [source.batch(i) for i in range(lo, hi)]
        keys = sorted(chunk[0])
        np.savez(_shard_path(directory, c),
                 **{k: np.stack([b[k] for b in chunk]) for k in keys})
    manifest = {
        "format": _FORMAT,
        "batch_size": int(source.batch_size),
        "num_batches": int(n),
        "batches_per_chunk": int(batches_per_chunk),
        "num_chunks": int(num_chunks),
        "keys": keys,
        # duck-typed sources only promise batch/batch_size/num_batches
        "source": getattr(source, "name", type(source).__name__),
    }
    tmp = os.path.join(directory, _MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, _MANIFEST))
    return manifest


@register_source("file_sparse")
class FileSparseSource(DataSource):
    """Read-side of `write_file_corpus`: seekable batches out of chunk files.

    Random access loads the containing chunk into a small LRU cache
    (`cache_chunks` slots, default 2 so two interleaved readers — e.g. two
    prefetching loaders sharing one source — don't thrash; guarded by a
    lock because a ShardedLoader's prefetch thread calls `batch` from a
    background thread). Sequential reads touch each file once; seeking
    (resume) costs one chunk read.

    `owned_shards` divides the corpus into contiguous, chunk-aligned
    per-host ranges (the tentpole of multi-process ownership): host h of H
    owns a balanced ⌈C/H⌉-or-⌊C/H⌋ chunk range and never opens the rest.
    `read_stats` counts actual chunk-file opens, so tests and
    `benchmarks/shard_ownership.py` can assert the locality claim.
    """

    name = "file_sparse"

    def __init__(self, directory: str, cache_chunks: int = 2):
        self.directory = directory
        with open(os.path.join(directory, _MANIFEST)) as f:
            self.manifest = json.load(f)
        if self.manifest.get("format") != _FORMAT:
            raise ValueError(f"{directory}: not a {_FORMAT} corpus "
                             f"({self.manifest.get('format')!r})")
        self.batch_size = int(self.manifest["batch_size"])
        self.num_batches = int(self.manifest["num_batches"])
        self.batches_per_chunk = int(self.manifest["batches_per_chunk"])
        self.num_chunks = int(self.manifest["num_chunks"])
        self.cache_chunks = max(1, int(cache_chunks))
        self._lock = threading.Lock()
        self._cache: dict[int, dict[str, np.ndarray]] = {}
        self._chunk_loads = 0
        self._chunks_touched: set = set()

    def owned_shards(self, host: int, num_hosts: int) -> ShardAssignment:
        """Chunk-aligned contiguous ownership computed from the manifest."""
        a = ShardAssignment.chunk_aligned(
            self.num_chunks, num_hosts,
            batches_per_chunk=self.batches_per_chunk,
            num_batches=self.num_batches)
        a._check_host(host)
        return a

    @property
    def read_stats(self) -> dict[str, int]:
        """Chunk-file I/O since construction: `chunk_loads` counts every
        np.load (cache misses included re-reads), `unique_chunks` the
        distinct files touched — the number a host under chunk ownership
        keeps at ⌈C/H⌉ instead of C."""
        with self._lock:
            return {"chunk_loads": self._chunk_loads,
                    "unique_chunks": len(self._chunks_touched)}

    def batch(self, index: int) -> dict[str, np.ndarray]:
        self._check_index(index)
        chunk, off = divmod(index, self.batches_per_chunk)
        with self._lock:
            arrs = self._cache.pop(chunk, None)
            if arrs is None:
                with np.load(_shard_path(self.directory, chunk)) as z:
                    arrs = {k: z[k] for k in self.manifest["keys"]}
                self._chunk_loads += 1
                self._chunks_touched.add(chunk)
            self._cache[chunk] = arrs        # most recently used last
            while len(self._cache) > self.cache_chunks:
                self._cache.pop(next(iter(self._cache)))
            # copies, not views: a consumer mutating its batch in place must
            # not corrupt the cache (batch(index) purity is what resume
            # exactness rests on)
            return {k: v[off].copy() for k, v in arrs.items()}
