"""Shard ownership: which host reads which slice of a corpus.

The paper's data plane starts from samples that already live pre-sharded
in a distributed file system: every node maps only over the sample shards
co-located with it. A `ShardAssignment` makes that ownership first-class —
a global map from host to the contiguous, chunk-aligned range of chunk
files it owns, computed once from the corpus manifest:

    manifest.json ──► ShardAssignment.chunk_aligned(C, H, ...)
      num_chunks=C         host 0   ──► chunks [0,  q0)   q = ⌈C/H⌉ or
      batches_per_chunk    host 1   ──► chunks [q0, q1)       ⌊C/H⌋ each,
      num_batches          ...                                balanced
                           host H-1 ──► chunks [..,  C)

Invariants (asserted in tests/test_ownership.py):

  - every chunk is owned by exactly ONE host; none are dropped;
  - each host's range is contiguous and chunk-aligned, so host h opens
    only its own <= ⌈C/H⌉ chunk files (not all C — the stride baseline's
    H× read amplification), and whenever C >= H every host owns at
    least one chunk (balanced split, not the starving ⌈C/H⌉-greedy one);
  - with H > C the trailing hosts own nothing (their loaders refuse to
    construct rather than silently serving an empty epoch);
  - the last chunk may be short (num_batches % batches_per_chunk != 0) —
    per-host epoch lengths are exact batch counts, not floors.

Synthetic sources have no files to own; they declare the `stride` kind
(host h reads batches h, h+H, ... — the pre-ownership interleaving) so the
loader can record what geometry a cursor was written against.

`reassign_state` is the elastic-rescale hook (re-exported as
`runtime/elastic.py::reshard_data_state`): a loader `state_dict()` recorded
under one host count is rewritten for another — the epoch survives, the
host-local step resets to the epoch start, and the new loader recomputes
its own assignment, mirroring how the per-device strategy carry is reset
on mesh rescale. Correctness over exactness: under the new assignment
every chunk is again owned exactly once, at the cost of re-reading the
interrupted epoch.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ShardAssignment", "reassign_state"]


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    """Global host → shard-range map for one corpus geometry.

    kind:              "chunk" (file-backed, chunk-aligned contiguous
                       ranges) or "stride" (synthetic interleaving)
    num_hosts:         hosts the corpus is divided over
    num_batches:       global epoch size in batches
    batches_per_chunk / num_chunks / chunk_ranges:
                       chunk-kind geometry; `chunk_ranges[h] == (lo, hi)`
                       is host h's half-open chunk range
    """

    kind: str
    num_hosts: int
    num_batches: int
    batches_per_chunk: int = 0
    num_chunks: int = 0
    chunk_ranges: tuple = ()

    # -- constructors -------------------------------------------------------

    @classmethod
    def chunk_aligned(cls, num_chunks: int, num_hosts: int, *,
                      batches_per_chunk: int,
                      num_batches: int) -> "ShardAssignment":
        """Balanced contiguous ranges: ⌊C/H⌋ chunks each, the first C % H
        hosts take one extra (so every range holds ⌈C/H⌉ or ⌊C/H⌋ chunks).

        NOT the naive ⌈C/H⌉-greedy split, which starves trailing hosts of
        perfectly divisible work — e.g. C=6, H=4 greedy gives (2,2,2,0)
        where balanced gives (2,2,1,1). A host owns nothing only when
        H > C leaves genuinely no chunk for it."""
        if num_chunks < 1 or num_hosts < 1:
            raise ValueError((num_chunks, num_hosts))
        base, extra = divmod(num_chunks, num_hosts)
        ranges = []
        lo = 0
        for h in range(num_hosts):
            hi = lo + base + (1 if h < extra else 0)
            ranges.append((lo, hi))
            lo = hi
        return cls(kind="chunk", num_hosts=int(num_hosts),
                   num_batches=int(num_batches),
                   batches_per_chunk=int(batches_per_chunk),
                   num_chunks=int(num_chunks), chunk_ranges=tuple(ranges))

    @classmethod
    def strided(cls, num_batches: int, num_hosts: int) -> "ShardAssignment":
        """The synthetic interleaving: host h owns batches h, h+H, ..."""
        return cls(kind="stride", num_hosts=int(num_hosts),
                   num_batches=int(num_batches))

    # -- queries ------------------------------------------------------------

    def _check_host(self, host: int) -> None:
        if not 0 <= host < self.num_hosts:
            raise ValueError(f"host {host} out of range for "
                             f"{self.num_hosts} hosts")

    def owned_chunks(self, host: int) -> range:
        """This host's contiguous chunk range (chunk kind only)."""
        self._check_host(host)
        if self.kind != "chunk":
            raise ValueError(f"{self.kind!r} assignments have no chunks")
        lo, hi = self.chunk_ranges[host]
        return range(lo, hi)

    def chunk_batches(self, chunk: int) -> range:
        """Global batch indices inside one chunk (last may be short)."""
        lo = chunk * self.batches_per_chunk
        return range(lo, min(self.num_batches,
                             lo + self.batches_per_chunk))

    def owned_batches(self, host: int) -> list[int]:
        """Global batch indices this host owns, in on-disk read order."""
        self._check_host(host)
        if self.kind == "stride":
            return list(range(host, self.num_batches, self.num_hosts))
        return [i for c in self.owned_chunks(host)
                for i in self.chunk_batches(c)]

    def steps_per_epoch(self, host: int) -> int:
        """Batches this host consumes per epoch.

        Chunk kind: the exact owned count (uneven across hosts when
        C % H != 0 or the last chunk is short). Stride kind: the even
        floor `num_batches // num_hosts` every host can serve."""
        self._check_host(host)
        if self.kind == "stride":
            return self.num_batches // self.num_hosts
        return len(self.owned_batches(host))

    def chunk_owner(self, chunk: int) -> int:
        """The single host owning `chunk` (chunk kind only)."""
        for h, (lo, hi) in enumerate(self.chunk_ranges):
            if lo <= chunk < hi:
                return h
        raise ValueError(f"chunk {chunk} outside [0, {self.num_chunks})")

    def global_rows(self, host: int, batch_size: int) -> range:
        """Rows host `host`'s per-step batch occupies in the assembled
        GLOBAL batch: `[host*B, (host+1)*B)`.

        A real multi-process run (`runtime/multiprocess.py`) glues the
        per-host batches into one `num_hosts*B`-row global array per step
        via `make_array_from_process_local_data`, with process h's local
        devices holding exactly these rows; the single-process parity
        baseline (`--host-id -1`) concatenates the same streams in the
        same host order. One definition, both execution modes."""
        self._check_host(host)
        if batch_size < 1:
            raise ValueError(f"batch_size must be positive: {batch_size}")
        return range(host * batch_size, (host + 1) * batch_size)

    # -- (de)serialization — JSON-native, rides in checkpoint extras --------

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "num_hosts": self.num_hosts,
             "num_batches": self.num_batches}
        if self.kind == "chunk":
            d.update(batches_per_chunk=self.batches_per_chunk,
                     num_chunks=self.num_chunks,
                     chunk_ranges=[list(r) for r in self.chunk_ranges])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ShardAssignment":
        return cls(kind=d["kind"], num_hosts=int(d["num_hosts"]),
                   num_batches=int(d["num_batches"]),
                   batches_per_chunk=int(d.get("batches_per_chunk", 0)),
                   num_chunks=int(d.get("num_chunks", 0)),
                   chunk_ranges=tuple(tuple(r) for r
                                      in d.get("chunk_ranges", ())))


def reassign_state(state: dict, num_hosts: int,
                   host_index: int | None = None) -> dict:
    """Rewrite a loader `state_dict()` for a NEW host count.

    The host-local step of the saved cursor addresses the OLD assignment's
    stream — under a different host count it would point at someone else's
    samples. Reassignment keeps what is still meaningful (the epoch — and
    with it the shuffle permutations) and resets the step to the epoch
    start; the restoring loader recomputes its own chunk range, so every
    chunk is again owned exactly once and none are dropped.
    """
    cur = dict(state.get("cursor") or {})
    new = dict(state)
    new["cursor"] = {"epoch": int(cur.get("epoch", 0)), "step": 0}
    new["num_hosts"] = int(num_hosts)
    if host_index is not None:
        new["host_index"] = int(host_index)
    else:
        new.pop("host_index", None)
    new.pop("assignment", None)     # stale geometry: loader recomputes
    return new
