"""`ShardedLoader` — the prefetching, resumable front of the data plane.

One loader owns everything between a `DataSource` and the training step:

  shard ownership   the source's `owned_shards(host, num_hosts)` seam
                    decides what host h of H reads. File-backed sources
                    (`file_sparse`) return chunk-aligned contiguous ranges:
                    host h owns a balanced run of ⌈C/H⌉ or ⌊C/H⌋ chunk
                    files and OPENS ONLY THOSE — the paper's per-node
                    HDFS blocks,
                    with `steps_per_epoch` the exact owned batch count
                    (uneven across hosts when C % H != 0). Synthetic sources
                    declare the `stride` kind (host h reads global batches
                    h, h+H, ...; `steps_per_epoch` is the even floor
                    `num_batches // H`); `ownership="stride"` forces that
                    interleaving on any source (the pre-ownership baseline,
                    with its H× file-read amplification).
  conformance       global batch size must divide by the mesh's shard count
                    P (shard_map constraint); the loader drops the remainder
                    rows (default) or zero-pads (`remainder="pad"`; sparse
                    `ids` pad with -1 == empty slots).
  placement         "sharded" device_puts every leaf sharded over all mesh
                    axes (what the DPMR sparse step expects), "device" is a
                    plain `jnp.asarray` (dense trainer), "host" yields
                    numpy, or pass any callable(batch) -> batch.
  prefetch          a daemon thread synthesizes + places the next batches
                    while the consumer runs the training step; a bounded
                    queue of DEVICE-resident batches (default depth 2) gives
                    double-buffering, so host batch synthesis and H2D copy
                    overlap compute instead of serializing with it.
  cursor            an explicit (epoch, step) position. Batch content is a
                    pure function of `(epoch, step)` — of `step` alone with
                    shuffling off (epochs re-read the same shard in the same
                    order, the paper's full-batch regime) — so `seek(cursor)`
                    after a restore reproduces the continued stream
                    bit-for-bit. The cursor only advances when a batch is
                    HANDED to the consumer — the prefetch thread running
                    ahead never moves it, so a checkpoint taken mid-stream
                    is exact.
  shuffling         `shuffle=True` visits each epoch's batches in a fresh
                    pseudorandom order. Stride mode: a global permutation
                    seeded by `(shuffle_seed, epoch)` is striped over hosts.
                    Chunk-ownership mode: the permutation is over CHUNKS
                    WITHIN THIS OWNER — seeded by `(shuffle_seed, epoch,
                    host)` — and batches inside a chunk stay consecutive,
                    so shuffling never breaks chunk locality (each owned
                    file is still read once, sequentially). Either way
                    hosts stay disjoint and resume-exactness is preserved
                    (the permutation is recomputed from the cursor's
                    epoch, never stored). Chunk mode covers exactly the
                    owned batch set every epoch; stride mode covers the
                    first H*(n//H) entries of each epoch's permutation, so
                    when H does not divide n the dropped tail differs
                    between epochs.

    loader = ShardedLoader(get_source("zipf_sparse", batch_size=512,
                                      num_batches=8), mesh)
    for batch in loader.batches(40): ...   # 40 steps, epochs roll over
    for batch in loader.epoch(): ...       # remainder of the current epoch
    ck = loader.state_dict()               # {"cursor": {"epoch": e, "step": s}}
    loader.load_state_dict(ck)             # exact resume
"""
from __future__ import annotations

from collections.abc import Callable, Iterator
import dataclasses
import queue
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.ownership import ShardAssignment, reassign_state
from repro.data.sources import DataSource


def put_sharded(batch: dict, mesh) -> dict:
    """Host→device placement: every batch leaf sharded over all mesh axes.

    THE definition of sparse-face placement — `repro.api.engine.put_batch`
    delegates here. Leaves already under the target sharding (a loader
    prefetched and placed them) pass through untouched."""
    sharding = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    out = {}
    for k, v in batch.items():
        if isinstance(v, jax.Array) and v.sharding == sharding:
            out[k] = v
        else:
            out[k] = jax.device_put(jnp.asarray(v), sharding)
    return out


@dataclasses.dataclass(frozen=True)
class Cursor:
    """Explicit stream position: `epoch` full passes done, `step` batches
    consumed within the current pass (local to this host's shard)."""

    epoch: int = 0
    step: int = 0

    def to_dict(self) -> dict[str, int]:
        return {"epoch": int(self.epoch), "step": int(self.step)}

    @classmethod
    def from_dict(cls, d: dict) -> "Cursor":
        return cls(epoch=int(d["epoch"]), step=int(d["step"]))


class ShardedLoader:
    """Per-host sharded, conforming, prefetching view of a `DataSource`.

    Parameters
    ----------
    source:        any DataSource (see repro.data.sources)
    mesh:          jax Mesh; sets the default batch divisor (shard count P)
                   and the "sharded" placement target. Optional for
                   host-only use.
    placement:     "sharded" | "device" | "host" | callable(batch) -> batch
    host_index / num_hosts:
                   this process's slice of the batch stream; default
                   jax.process_index()/process_count()
    ownership:     "auto" (default) asks the source's `owned_shards(host,
                   num_hosts)` seam — file-backed sources return
                   chunk-aligned contiguous per-host ranges so this host
                   opens only its own chunk files; "stride" forces the
                   synthetic interleaving (host h reads batches h, h+H,
                   ...) on any source, the pre-ownership baseline
    batch_divisor: override the divisibility constraint (default: product
                   of mesh axis sizes under "sharded", else 1)
    remainder:     "drop" (default) or "pad" when batch_size % divisor != 0.
                   Pad rows are EMPTY samples (ids=-1, vals=0, labels=0):
                   they contribute no feature gradients, but they do count
                   in loss/accuracy denominators and in PRF metrics — keep
                   "drop" for anything metrics-sensitive
    prefetch:      queue depth of placed batches built ahead by a background
                   thread; 0 = fully synchronous
    epoch_size:    batches per epoch for UNBOUNDED sources (required by
                   `epoch()`; bounded sources define it themselves)
    cursor:        starting position (default (0, 0))
    shuffle:       per-epoch shuffling — each epoch reads the same batch set
                   in a fresh order given by a permutation seeded with
                   `(shuffle_seed, epoch)`. Requires a bounded epoch (a
                   bounded source or `epoch_size`). Resume stays exact:
                   the permutation is a pure function of the cursor's epoch
    shuffle_seed:  base seed of the per-epoch permutations
    """

    def __init__(self, source: DataSource, mesh=None, *,
                 placement: str | Callable = "sharded",
                 host_index: int | None = None,
                 num_hosts: int | None = None,
                 ownership: str = "auto",
                 batch_divisor: int | None = None,
                 remainder: str = "drop",
                 prefetch: int = 2,
                 epoch_size: int | None = None,
                 cursor: Cursor | None = None,
                 shuffle: bool = False,
                 shuffle_seed: int = 0):
        self.source = source
        # duck-typed sources only promise batch/batch_size/num_batches
        self.source_name = getattr(source, "name", type(source).__name__)
        self.mesh = mesh
        self.placement = placement
        self.num_hosts = int(num_hosts if num_hosts is not None
                             else jax.process_count())
        self.host_index = int(host_index if host_index is not None
                              else jax.process_index())
        if not 0 <= self.host_index < self.num_hosts:
            raise ValueError((self.host_index, self.num_hosts))
        if remainder not in ("drop", "pad"):
            raise ValueError(f"remainder must be 'drop'|'pad': {remainder!r}")
        self.remainder = remainder
        self.prefetch = int(prefetch)
        self._sharding = None
        if placement == "sharded":
            if mesh is None:
                raise ValueError("placement='sharded' needs a mesh")
            self._sharding = NamedSharding(mesh, P(tuple(mesh.axis_names)))
        if batch_divisor is None:
            batch_divisor = 1
            if self._sharding is not None:
                for a in mesh.axis_names:
                    batch_divisor *= int(mesh.shape[a])
        self.batch_divisor = int(batch_divisor)

        # -- shard ownership: what does host h of H read? -------------------
        if ownership not in ("auto", "stride"):
            raise ValueError(f"ownership must be 'auto'|'stride': "
                             f"{ownership!r}")
        assignment = None
        if ownership == "auto":
            seam = getattr(source, "owned_shards", None)
            if callable(seam):
                assignment = seam(self.host_index, self.num_hosts)
        # stride-kind declarations keep the legacy index arithmetic below;
        # only chunk-kind assignments change the iteration order contract
        self._assignment = assignment if (
            assignment is not None and assignment.kind == "chunk") else None
        self.assignment_kind = "chunk" if self._assignment is not None \
            else "stride"

        if self._assignment is not None:
            if epoch_size is not None:
                raise ValueError(
                    "epoch_size= conflicts with chunk ownership: the epoch "
                    "is this host's owned chunk range; pass "
                    "ownership='stride' to override the source's assignment")
            n = self._assignment.num_batches
            self.steps_per_epoch = self._assignment.steps_per_epoch(
                self.host_index)
            if self.steps_per_epoch < 1:
                raise ValueError(
                    f"host {self.host_index} of {self.num_hosts} owns no "
                    f"chunks: the corpus has only "
                    f"{self._assignment.num_chunks} chunk files; use fewer "
                    "hosts or re-chunk the corpus with a smaller "
                    "batches_per_chunk")
        else:
            n = epoch_size if epoch_size is not None else source.num_batches
            self.steps_per_epoch = None if n is None \
                else int(n) // self.num_hosts
            if self.steps_per_epoch is not None and self.steps_per_epoch < 1:
                raise ValueError(
                    f"source has {n} batches for {self.num_hosts} hosts: "
                    "fewer than one batch per host per epoch")
        self.shuffle = bool(shuffle)
        self.shuffle_seed = int(shuffle_seed)
        if self.shuffle and n is None:
            raise ValueError(
                "shuffle=True needs a bounded epoch to permute: give the "
                "source a num_batches or pass epoch_size=")
        self._epoch_batches = None if n is None else int(n)
        self._perm_cache = (None, None)   # (epoch, permutation)
        self._order_cache = (None, None)  # (epoch, owned batch order)
        self._cursor = cursor if cursor is not None else Cursor()
        self._seek_token = 0   # bumped by seek(); invalidates live iterators

    @property
    def assignment(self) -> ShardAssignment | None:
        """The global chunk `ShardAssignment` in force, or None when this
        loader reads by stride (synthetic sources, ownership='stride')."""
        return self._assignment

    # -- cursor -------------------------------------------------------------

    @property
    def cursor(self) -> Cursor:
        return self._cursor

    def seek(self, cursor: Cursor | dict) -> None:
        """Reposition the stream; the next batch is the one an uninterrupted
        run would have produced at this cursor.

        Any iterator already obtained from batches()/epoch() planned its
        positions from the OLD cursor — resuming one after a seek raises
        RuntimeError rather than silently serving stale positions."""
        if isinstance(cursor, dict):
            cursor = Cursor.from_dict(cursor)
        self._seek_token += 1
        self._cursor = cursor

    def state_dict(self) -> dict:
        d = {"cursor": self._cursor.to_dict(),
             "source": self.source_name,
             "batch_size": int(getattr(self.source, "batch_size", 0)),
             "num_hosts": self.num_hosts,
             "host_index": self.host_index,
             "ownership": self.assignment_kind,
             "shuffle": self.shuffle,
             "shuffle_seed": self.shuffle_seed}
        if self._assignment is not None:
            d["assignment"] = self._assignment.to_dict()
        return d

    def load_state_dict(self, state: dict, *,
                        on_host_change: str = "error") -> None:
        """Restore a `state_dict()` position, validating that the stream it
        was recorded against is the one this loader reads.

        `on_host_change` decides what happens when the state was recorded
        under a DIFFERENT host count (elastic rescale): "error" (default)
        refuses — the host-local step addresses someone else's stream —
        while "reassign" rewrites the state via
        `repro.data.ownership.reassign_state` (the epoch survives, the step
        resets to the epoch start, this loader's own assignment takes
        over; every chunk is owned exactly once under the new geometry)."""
        if on_host_change not in ("error", "reassign"):
            raise ValueError(f"on_host_change must be 'error'|'reassign': "
                             f"{on_host_change!r}")
        saved_hosts = state.get("num_hosts")
        if saved_hosts is not None and int(saved_hosts) != self.num_hosts:
            if on_host_change == "reassign":
                warnings.warn(
                    f"cursor was recorded with num_hosts={saved_hosts}; "
                    f"reassigning shards over {self.num_hosts} hosts — "
                    "resuming at the start of epoch "
                    f"{int(state.get('cursor', {}).get('epoch', 0))} "
                    "(correct-by-reassignment, not bit-exact: the "
                    "interrupted epoch is re-read under the new ownership)",
                    RuntimeWarning, stacklevel=2)
                state = reassign_state(state, self.num_hosts,
                                       self.host_index)
            else:
                raise ValueError(
                    f"cursor was recorded with num_hosts={saved_hosts} but "
                    f"this loader shards over {self.num_hosts} hosts — the "
                    "host-local step would address a different sample "
                    "stream; pass on_host_change='reassign' (or rewrite the "
                    "state with runtime/elastic.py::reshard_data_state) to "
                    "resume at the epoch boundary under the new assignment")
        saved_host = state.get("host_index")
        if saved_host is not None and int(saved_host) != self.host_index:
            warnings.warn(
                f"cursor was recorded by host {saved_host} but this loader "
                f"is host {self.host_index}; the step addresses that "
                "host's shard — resume is only exact on the recording host",
                RuntimeWarning, stacklevel=2)
        saved_kind = state.get("ownership")
        if saved_kind is not None and saved_kind != self.assignment_kind:
            warnings.warn(
                f"cursor was recorded under {saved_kind!r} ownership but "
                f"this loader reads by {self.assignment_kind!r}; the step "
                "index addresses a differently-ordered stream — resume is "
                "not exact", RuntimeWarning, stacklevel=2)
        saved_assign = state.get("assignment")
        if (saved_assign is not None and self._assignment is not None
                and int(saved_assign.get("num_hosts", self.num_hosts))
                == self.num_hosts
                and saved_assign != self._assignment.to_dict()):
            warnings.warn(
                "cursor was recorded against a different chunk assignment "
                f"({saved_assign.get('num_chunks')} chunks x "
                f"{saved_assign.get('batches_per_chunk')} batches) than "
                f"this corpus ({self._assignment.num_chunks} x "
                f"{self._assignment.batches_per_chunk}); the step "
                "addresses different samples — resume is not exact",
                RuntimeWarning, stacklevel=2)
        saved_source = state.get("source")
        if saved_source is not None and saved_source != self.source_name:
            warnings.warn(
                f"restoring a cursor recorded against source "
                f"{saved_source!r} into a {self.source_name!r} loader; "
                "resume is only exact if both serve identical batches",
                RuntimeWarning, stacklevel=2)
        saved_shuffle = state.get("shuffle")
        if saved_shuffle is not None and bool(saved_shuffle) != self.shuffle:
            warnings.warn(
                f"cursor was recorded with shuffle={saved_shuffle} but this "
                f"loader has shuffle={self.shuffle}; the step index "
                "addresses a differently-ordered stream — resume is not "
                "exact", RuntimeWarning, stacklevel=2)
        saved_sseed = state.get("shuffle_seed")
        if (self.shuffle and saved_sseed is not None
                and int(saved_sseed) != self.shuffle_seed):
            warnings.warn(
                f"cursor was recorded with shuffle_seed={saved_sseed} but "
                f"this loader uses shuffle_seed={self.shuffle_seed}; the "
                "epoch permutations differ — resume is not exact",
                RuntimeWarning, stacklevel=2)
        saved_bs = state.get("batch_size")
        here_bs = int(getattr(self.source, "batch_size", 0))
        if saved_bs and here_bs and int(saved_bs) != here_bs:
            warnings.warn(
                f"cursor was recorded against batch_size={saved_bs} but "
                f"this loader's source serves batch_size={here_bs}; the "
                "step index addresses different samples — resume is not "
                "exact", RuntimeWarning, stacklevel=2)
        self.seek(Cursor.from_dict(state["cursor"]))

    # -- iteration ----------------------------------------------------------

    def batches(self, limit: int | None = None) -> Iterator[dict]:
        """Yield up to `limit` placed batches from the cursor onward,
        rolling over epochs on bounded sources (None = unbounded stream).

        One live iterator at a time: starting a new one (like seek) stales
        any earlier iterator's plan — resuming the old one raises
        RuntimeError instead of serving duplicate positions."""
        self._seek_token += 1
        token = self._seek_token
        plan = self._positions(self._cursor, limit)
        if self.prefetch <= 0:
            for pos, after in plan:
                self._check_token(token)
                batch = self._place(self._load(pos))
                self._cursor = after
                yield batch
            return
        yield from self._prefetched(plan, token)

    def epoch(self, from_start: bool = False) -> Iterator[dict]:
        """The remainder of the current epoch (or, with `from_start`, the
        whole current epoch); afterwards the cursor sits at the next epoch's
        start. One call == one full pass of this host's shard — the paper's
        per-iteration corpus sweep."""
        spe = self.steps_per_epoch
        if spe is None:
            raise ValueError(
                f"source {self.source_name!r} is unbounded, so an epoch is "
                "undefined: give the source a bounded num_batches (e.g. "
                "num_batches= in the spec passed to get_source) or pass "
                "epoch_size= when constructing the ShardedLoader")

        def gen():
            # everything binds at ITERATION time, not at epoch() call time:
            # if the cursor moved in between (another take(), a seek), the
            # pass still ends exactly at the next epoch boundary instead of
            # spilling a stale batch count into the following epoch
            if self._cursor.step >= spe:
                # normalize an epoch-boundary/overshot cursor the same way
                # _positions() would, so the limit never goes negative
                self._cursor = Cursor(self._cursor.epoch + 1, 0)
            if from_start and self._cursor.step != 0:
                self._cursor = Cursor(self._cursor.epoch, 0)
            yield from self.batches(spe - self._cursor.step)

        return gen()

    def take(self, n: int) -> list:
        return list(self.batches(n))

    # -- internals ----------------------------------------------------------

    def _check_token(self, token: int) -> None:
        if token != self._seek_token:
            raise RuntimeError(
                "loader was repositioned (seek/load_state_dict) or a newer "
                "iterator was started while this iterator was active; its "
                "remaining plan is stale — create a new iterator with "
                "batches()/epoch()")

    def _positions(self, start: Cursor, limit: int | None
                   ) -> Iterator[tuple]:
        """(position, cursor-after) pairs from `start`, epoch-rolling."""
        spe = self.steps_per_epoch
        cur = start
        produced = 0
        while limit is None or produced < limit:
            if spe is not None and cur.step >= spe:
                cur = Cursor(cur.epoch + 1, 0)
            nxt = Cursor(cur.epoch, cur.step + 1)
            if spe is not None and nxt.step >= spe:
                nxt = Cursor(cur.epoch + 1, 0)
            yield cur, nxt
            cur = nxt
            produced += 1

    def _permutation(self, epoch: int) -> np.ndarray:
        """The epoch's global batch permutation — a pure function of
        (shuffle_seed, epoch), so seeking reconstructs it exactly."""
        cached_epoch, perm = self._perm_cache
        if cached_epoch != epoch:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.shuffle_seed, epoch]))
            perm = rng.permutation(self._epoch_batches)
            self._perm_cache = (epoch, perm)
        return perm

    def _owned_order(self, epoch: int) -> np.ndarray:
        """Chunk-ownership read order for one epoch: this host's owned
        chunks — permuted per epoch when shuffling, seeded by
        (shuffle_seed, epoch, host) so hosts draw independent orders —
        with batches inside each chunk kept consecutive (every owned file
        is read once, sequentially). A pure function of the cursor's
        epoch, so seeking reconstructs it exactly."""
        cached_epoch, order = self._order_cache
        if cached_epoch != epoch:
            a = self._assignment
            chunks = list(a.owned_chunks(self.host_index))
            if self.shuffle:
                rng = np.random.default_rng(np.random.SeedSequence(
                    [self.shuffle_seed, epoch, self.host_index]))
                chunks = [chunks[i] for i in rng.permutation(len(chunks))]
            order = np.asarray([i for c in chunks
                                for i in a.chunk_batches(c)], dtype=np.int64)
            self._order_cache = (epoch, order)
        return order

    def _load(self, pos: Cursor) -> dict[str, np.ndarray]:
        # content is a pure function of the cursor: without shuffling it
        # depends only on `step` (every epoch re-reads the same shard in
        # the same order, the deterministic full-batch regime); with
        # shuffling the epoch's permutation reorders the same batch set
        if self._assignment is not None:
            index = int(self._owned_order(pos.epoch)[pos.step])
        else:
            index = pos.step * self.num_hosts + self.host_index
            if self.shuffle:
                index = int(self._permutation(pos.epoch)[index])
        return self._conform(self.source.batch(index))

    def _conform(self, batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        d = self.batch_divisor
        b = next(iter(batch.values())).shape[0]
        rem = b % d
        if rem == 0:
            return batch
        if self.remainder == "drop":
            keep = b - rem
            if keep == 0:
                raise ValueError(
                    f"batch of {b} samples smaller than the mesh divisibility "
                    f"constraint {d}; use remainder='pad' or a larger batch")
            return {k: v[:keep] for k, v in batch.items()}
        pad = d - rem
        out = {}
        for k, v in batch.items():
            fill_val = -1 if k == "ids" else 0
            fill = np.full((pad,) + v.shape[1:], fill_val, v.dtype)
            out[k] = np.concatenate([np.asarray(v), fill], axis=0)
        return out

    def _place(self, batch: dict[str, np.ndarray]) -> dict:
        if callable(self.placement):
            return self.placement(batch)
        if self.placement == "sharded":
            return put_sharded(batch, self.mesh)
        if self.placement == "device":
            return {k: jnp.asarray(v) for k, v in batch.items()}
        if self.placement == "host":
            return batch
        raise ValueError(f"unknown placement {self.placement!r}")

    def _prefetched(self, plan: Iterator[tuple],
                    token: int) -> Iterator[dict]:
        """Background-thread synthesis + placement, bounded-queue delivery.

        The cursor advances on the CONSUMER side as batches are handed out;
        the producer running ahead never moves it, so checkpoints taken
        between steps are exact resume points.
        """
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def offer(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer():
            try:
                for pos, after in plan:
                    if stop.is_set():
                        return
                    if not offer(("batch", self._place(self._load(pos)),
                                  after)):
                        return
                offer(("done", None, None))
            except BaseException as e:  # surface in the consumer
                offer(("error", e, None))

        thread = threading.Thread(target=producer, daemon=True,
                                  name="sharded-loader-prefetch")
        thread.start()
        try:
            while True:
                kind, payload, after = q.get()
                if kind == "done":
                    return
                if kind == "error":
                    raise payload
                self._check_token(token)
                self._cursor = after
                yield payload
        finally:
            stop.set()
            thread.join(timeout=5.0)
