"""`DPMREngine` — the typed façade over the DPMR sparse core.

One object owns the compiled step functions (`StepFns`), the sharded
`DPMRState`, host→device batch placement, the optimizer/schedule selection,
and the checkpoint story:

    from repro.api import DPMREngine

    eng = DPMREngine(cfg, mesh, hot_ids=hot)
    eng.fit_sgd(batches, steps=100)        # minibatch SGD
    eng.fit(batch_iter_fn)                 # paper-regime full-batch GD
    probs = eng.predict(batch)
    metrics = eng.evaluate(test_batches)
    eng.save("/ckpt/dir"); eng.restore("/ckpt/dir")

The data arguments of `fit` / `fit_sgd` / `evaluate` accept, besides plain
iterables, anything from the `repro.data` plane: a `ShardedLoader`, a
`DataSource`, or a registered source name + spec kwargs —

    eng.fit_sgd("zipf_sparse", steps=40,
                spec=dict(batch_size=512, num_features=1 << 14))

A loader's resumable cursor rides along in `save()` / `restore()` extras, so
a restored engine + loader continues the exact batch stream an uninterrupted
run would have seen.

Step functions are compiled lazily per global batch size and LRU-cached
(`max_cached_fns`), so one engine serves training and differently-sized eval
batches without retaining every compilation forever. The distribution
strategy (`cfg.distribution`) is resolved through the registry in
`repro.api.strategies`.

The updating steps donate the consumed state (`core.dpmr.StepFns`), so
`engine.state` always points at live buffers but any OLD reference to it
dies with the next `train_step`/`fit`; snapshot with
`jax.tree.map(jnp.copy, engine.state)` if you need a pre-step copy.
"""
from __future__ import annotations

from collections.abc import Callable, Iterable
import itertools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.api.strategies import list_strategies
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import DPMRConfig
from repro.core import dpmr, hot_sharding
from repro.core.dpmr import StepFns
from repro.data import DataSource, ShardedLoader, get_source
from repro.data.loader import put_sharded
from repro.kernels import ops
from repro.runtime import multiprocess


def put_batch(batch: dict, mesh) -> dict:
    """Host→device placement: every batch leaf sharded over all mesh axes.

    Delegates to `repro.data.loader.put_sharded` — the single definition the
    ShardedLoader's "sharded" placement also uses — so leaves a loader
    already placed pass through untouched."""
    return put_sharded(batch, mesh)


def binary_prf_metrics(predict_fn: Callable[[dict], np.ndarray],
                       test_batches: Iterable[dict]) -> dict:
    """Fig. 1 metrics: per-class precision/recall/F + macro average.

    `predict_fn(batch) -> probs`; batches must carry "labels".
    """
    tp = fp = fn_ = tn = 0
    for batch in test_batches:
        pred = (predict_fn(batch) >= 0.5).astype(np.int32)
        y = np.asarray(batch["labels"])
        tp += int(np.sum((pred == 1) & (y == 1)))
        fp += int(np.sum((pred == 1) & (y == 0)))
        fn_ += int(np.sum((pred == 0) & (y == 1)))
        tn += int(np.sum((pred == 0) & (y == 0)))

    def prf(tp, fp, fn):
        p = tp / max(tp + fp, 1)
        r = tp / max(tp + fn, 1)
        f = 2 * p * r / max(p + r, 1e-9)
        return p, r, f

    p1, r1, f1 = prf(tp, fp, fn_)
    p0, r0, f0 = prf(tn, fn_, fp)
    return {
        "precision_pos": p1, "recall_pos": r1, "f_pos": f1,
        "precision_neg": p0, "recall_neg": r0, "f_neg": f0,
        "precision_avg": (p1 + p0) / 2, "recall_avg": (r1 + r0) / 2,
        "f_avg": (f1 + f0) / 2,
    }


def hot_ids_from_corpus(cfg: DPMRConfig, sample_batches: Iterable[dict],
                        mesh) -> jax.Array:
    """initParameters-time frequency statistics -> replicated hot set."""
    f = dpmr.padded_features(cfg, mesh)
    counts = jnp.zeros((f,), jnp.int32)
    for b in sample_batches:
        counts = counts + hot_sharding.feature_counts(
            jnp.asarray(b["ids"]), f)
    return hot_sharding.select_hot(counts, cfg.hot_threshold, cfg.max_hot)


class DPMREngine:
    """Typed façade: state + compiled steps + checkpointing for sparse DPMR.

    Parameters
    ----------
    cfg:         DPMRConfig (features, strategy, optimizer, schedule, ...)
    mesh:        jax Mesh; every device is one DPMR node (samples + params)
    kernel_impl: hot-path lowering ("xla" | "pallas" | "pallas_interpret",
                 see repro.kernels.ops.KERNEL_IMPLS): the computeGradients
                 map body plus the routing kernels behind
                 StrategyContext.kernel_impl. None defers to
                 cfg.kernel_impl.
    cap_factor:  a2a capacity factor (slots per (src,dst) pair = cap_factor
                 x the uniform mean)
    hot_ids:     replicated Zipf-head ids (see `hot_ids_from_corpus`); None
                 disables hot replication
    state:       resume from an existing DPMRState instead of zeros
    max_cached_fns: LRU bound on the per-batch-size StepFns cache (bucketed
                 serving traffic would otherwise compile and retain one
                 entry per distinct batch size forever)
    """

    def __init__(self, cfg: DPMRConfig, mesh, *,
                 kernel_impl: str | None = None,
                 cap_factor: float = 4.0, hot_ids=None,
                 state: dpmr.DPMRState | None = None,
                 max_cached_fns: int = 8):
        self.cfg = cfg
        self.mesh = mesh
        self.kernel_impl = ops.normalize_impl(
            cfg.kernel_impl if kernel_impl is None else kernel_impl)
        self.cap_factor = cap_factor
        if max_cached_fns < 1:
            raise ValueError(f"max_cached_fns must be >= 1: {max_cached_fns}")
        self.max_cached_fns = max_cached_fns
        self._fns: dict[int, StepFns] = {}
        self._checkpointers: dict[str, Checkpointer] = {}
        self._loader: ShardedLoader | None = None
        self._schedule = dpmr.make_schedule(cfg)
        with compat.set_mesh(mesh):
            self.state = state if state is not None else dpmr.init_state(
                cfg, mesh, hot_ids)

    # -- step-function compilation cache ------------------------------------

    def step_fns(self, batch_size: int) -> StepFns:
        """Compiled StepFns for a given GLOBAL batch size (LRU-cached)."""
        fns = self._fns.pop(batch_size, None)
        if fns is None:
            with compat.set_mesh(self.mesh):
                fns = dpmr.make_step_fns(
                    self.cfg, self.mesh, batch_size,
                    kernel_impl=self.kernel_impl,
                    cap_factor=self.cap_factor)
        self._fns[batch_size] = fns     # move to the end: most recently used
        while len(self._fns) > self.max_cached_fns:
            self._fns.pop(next(iter(self._fns)))     # evict least recent
        return fns

    @property
    def fns(self) -> StepFns:
        """StepFns of the most recently used batch size."""
        if not self._fns:
            raise RuntimeError("no step fns compiled yet; run a step or "
                               "call engine.step_fns(batch_size)")
        return next(reversed(self._fns.values()))

    def put_batch(self, batch: dict) -> dict:
        return put_batch(batch, self.mesh)

    def learning_rate(self) -> float:
        """Schedule value at the current step."""
        return float(self._schedule(jnp.asarray(self.state.step)))

    # -- data-plane resolution ----------------------------------------------

    def _as_loader(self, data, spec: dict | None) -> \
            ShardedLoader | None:
        """Normalize a data argument to a ShardedLoader when it comes from
        the data plane (loader | DataSource | registered source name);
        returns None for plain iterables/callables."""
        # engine-built loaders are pinned to a single stream (host 0 of 1):
        # every process must place identical global batches under the mesh
        # sharding; per-host disjoint shards need global-array placement —
        # build your own ShardedLoader for that (cf. launch/train.make_loader)
        if isinstance(data, str):
            return ShardedLoader(get_source(data, **(spec or {})), self.mesh,
                                 host_index=0, num_hosts=1)
        if spec is not None:
            # anything non-str never reads spec — dropping it silently would
            # train on a differently-configured source than the caller asked
            raise TypeError("spec= is only meaningful with a source NAME; "
                            f"got {type(data).__name__} — configure the "
                            "source/loader directly instead")
        if isinstance(data, ShardedLoader):
            return data
        # duck-typed sources count too: register_source only requires
        # batch(index) / batch_size / num_batches, not the base class
        if isinstance(data, DataSource) or (
                hasattr(data, "batch") and hasattr(data, "batch_size")
                and hasattr(data, "num_batches")):
            return ShardedLoader(data, self.mesh, host_index=0, num_hosts=1)
        return None

    # -- training -----------------------------------------------------------

    def train_step(self, batch: dict) -> dict:
        """One minibatch update; returns host-side metrics."""
        fns = self.step_fns(len(batch["labels"]))
        with compat.set_mesh(self.mesh):
            self.state, m = fns.train_step(self.state,
                                           self.put_batch(batch))
        return {"loss": float(m["loss"]), "accuracy": float(m["accuracy"]),
                "overflow": int(m["overflow"])}

    def fit_sgd(self, data, steps: int | None = None, *,
                spec: dict | None = None) -> list[dict]:
        """Minibatch SGD (one update per batch); returns the history.

        `data`: iterable of batches, a `ShardedLoader`, a `DataSource`, or a
        registered source name (+ `spec` kwargs). With a loader, batches
        arrive prefetched/pre-placed and its cursor tracks progress for
        exact resume; `steps` bounds the number of updates. `steps=None` on
        a bounded loader trains the remainder of the current epoch (one
        corpus pass, the legacy generator behaviour); on an unbounded one
        it is an error rather than an infinite loop."""
        loader = self._as_loader(data, spec)
        if loader is not None:
            self._loader = loader
            if steps is None and loader.steps_per_epoch is None:
                raise ValueError(
                    "fit_sgd over an unbounded loader needs steps= (or give "
                    "the loader an epoch_size)")
            batches = loader.batches(steps) if steps is not None \
                else loader.epoch()
        else:
            batches = iter(data) if steps is None else \
                itertools.islice(iter(data), steps)
        history: list[dict] = []
        base = int(self.state.step)   # continue numbering across resumes
        for i, batch in enumerate(batches):
            m = self.train_step(batch)
            history.append({"step": base + i + 1, **m})
        return history

    def fit(self, data, iterations: int | None = None,
            eval_fn: Callable[["DPMREngine"], dict] | None = None, *,
            spec: dict | None = None) -> list[dict]:
        """Full-batch gradient descent: one update per ITERATION over the
        whole corpus (the paper's regime).

        `data`: a callable yielding the corpus in fixed-size batches each
        time it is called (legacy `batch_iter_fn`), or a `ShardedLoader` /
        `DataSource` / source name (+ `spec`) — then each iteration consumes
        one FULL loader epoch (a mid-epoch cursor is rewound to its epoch
        start, so every update averages the whole corpus as the paper
        regime requires; the cursor's epoch field counts iterations)."""
        loader = self._as_loader(data, spec)
        if loader is not None:
            self._loader = loader
            batch_iter_fn = lambda: loader.epoch(from_start=True)  # noqa: E731
        elif callable(data):
            batch_iter_fn = data
        else:
            raise TypeError(
                "fit() needs a batch_iter_fn callable, a ShardedLoader, a "
                f"DataSource, or a source name; got {type(data).__name__}")
        iterations = self.cfg.iterations if iterations is None else iterations
        history: list[dict] = []
        for it in range(iterations):
            acc_cold = jnp.zeros_like(self.state.cold)
            acc_hot = jnp.zeros_like(self.state.hot)
            tot_loss = tot_acc = 0.0
            nb = 0
            with compat.set_mesh(self.mesh):
                for batch in batch_iter_fn():
                    fns = self.step_fns(len(batch["labels"]))
                    gc, gh, m = fns.grad_step(self.state,
                                              self.put_batch(batch))
                    acc_cold = acc_cold + gc
                    acc_hot = acc_hot + gh
                    tot_loss += float(m["loss"])
                    tot_acc += float(m["accuracy"])
                    nb += 1
                if nb == 0:
                    raise ValueError(
                        "fit(): the corpus yielded no batches in iteration "
                        f"{it + 1} — an empty batch_iter_fn()/loader epoch "
                        "cannot produce an update")
                self.state = fns.apply_update(
                    self.state, acc_cold / nb, acc_hot / nb,
                    self.learning_rate())
            rec = {"iteration": it + 1, "loss": tot_loss / nb,
                   "accuracy": tot_acc / nb}
            if eval_fn is not None:
                rec.update(eval_fn(self))
            history.append(rec)
        return history

    # -- inference ----------------------------------------------------------

    def predict(self, batch: dict) -> np.ndarray:
        """Algorithm 9: probabilities for a test batch ({ids, vals}).

        Compiles (and LRU-caches) StepFns for this EXACT batch size — ad-hoc
        caller-shaped batches each cost a compilation and can thrash the
        cache under mixed request sizes. Serving paths should use
        `predict_padded`, which pads to a small ladder of bucketed sizes so
        the cache gets hits instead of recompiles."""
        fns = self.step_fns(len(batch["ids"]))
        with compat.set_mesh(self.mesh):
            probs = fns.predict(self.state, self.put_batch(
                {k: batch[k] for k in ("ids", "vals")}))
        # host_value, not np.asarray: under real multi-process execution
        # the result is a global array spanning processes, and every
        # process gets the full probability vector (collective gather)
        return multiprocess.host_value(probs)

    def bucket_for(self, n: int, buckets: Iterable[int] | None = None) -> int:
        """The padded batch size `predict_padded` would run `n` rows at.

        Default ladder: the smallest power-of-two multiple of the mesh shard
        count P that holds `n` (P, 2P, 4P, ...) — at most log2(max_batch)
        distinct compilations ever. An explicit `buckets` ladder must be
        multiples of P; `n` above the largest bucket is an error (split the
        batch instead of silently compiling an unplanned size)."""
        p = dpmr.num_shards(self.mesh)
        if n <= 0:
            raise ValueError(f"batch size must be positive: {n}")
        if buckets is None:
            return p * (1 << (-(-n // p) - 1).bit_length())
        for b in sorted(set(buckets)):
            if b % p:
                raise ValueError(
                    f"bucket {b} is not a multiple of the mesh shard "
                    f"count {p}")
            if b >= n:
                return b
        raise ValueError(
            f"batch of {n} rows exceeds the largest bucket in "
            f"{sorted(set(buckets))}")

    def predict_padded(self, batch: dict,
                       buckets: Iterable[int] | None = None) -> np.ndarray:
        """`predict` with the batch padded to a bucketed size, results
        sliced back to the caller's rows.

        Padding rows are empty samples (ids=-1, vals=0), which route nowhere
        and add no owner load, so the first `n` probabilities are
        bit-identical to `predict(batch)` — but every bucketed size hits the
        per-batch-size StepFns LRU cache instead of compiling a fresh entry
        per distinct request size. This is the serving predict path
        (`repro.serve.DPMRServeEngine` coalesces requests into it)."""
        ids = np.asarray(batch["ids"])
        vals = np.asarray(batch["vals"])
        n = len(ids)
        b = self.bucket_for(n, buckets)
        if b != n:
            pad = b - n
            ids = np.concatenate(
                [ids, np.full((pad, ids.shape[1]), -1, ids.dtype)])
            vals = np.concatenate(
                [vals, np.zeros((pad, vals.shape[1]), vals.dtype)])
        return self.predict({"ids": ids, "vals": vals})[:n]

    def evaluate(self, test_batches, *, spec: dict | None = None) -> dict:
        """Fig. 1 metrics: per-class precision/recall/F + macro average.

        `test_batches`: iterable of batches, or a `ShardedLoader` /
        `DataSource` / source name (+ `spec`) — then one full epoch of the
        test source is scored, and the loader's cursor is left exactly
        where it was (repeatable, and safe on a training loader whose
        resume position save() will persist)."""
        loader = self._as_loader(test_batches, spec)
        if loader is None:
            return binary_prf_metrics(self.predict, test_batches)
        mark = loader.cursor
        try:
            return binary_prf_metrics(self.predict,
                                      loader.epoch(from_start=True))
        finally:
            loader.seek(mark)

    # -- checkpointing -------------------------------------------------------

    def _checkpointer(self, directory: str, keep: int = 3) -> Checkpointer:
        """One long-lived Checkpointer per directory: `save(block=False)`
        hands its write thread to an object that survives until the next
        save (which joins it) — a throwaway instance per call would orphan
        the thread and allow two concurrent writers."""
        ck = self._checkpointers.get(directory)
        if ck is None:
            ck = self._checkpointers[directory] = Checkpointer(
                directory, keep=keep)
        ck.keep = keep
        return ck

    def wait_saves(self) -> None:
        """Join any in-flight async checkpoint writes (call before process
        exit; `save(block=True)` and every subsequent save also join)."""
        for ck in self._checkpointers.values():
            ck.wait()

    def save(self, directory: str, *, keep: int = 3, block: bool = True,
             loader: ShardedLoader | None = None) -> int:
        """Atomic checkpoint of the sparse state; returns the step saved.

        `block=False` keeps only the device->host snapshot on the step
        path and serializes/fsyncs on a background thread (the snapshot is
        taken before returning, so the training loop may immediately
        mutate/donate the live state). Under real multi-process execution
        every process must call this (the gather is collective); only
        process 0 writes.

        The data cursor of `loader` (default: the last loader handed to
        fit/fit_sgd) is persisted in the manifest extras, so restore resumes
        the exact batch stream."""
        loader = loader if loader is not None else self._loader
        step = int(self.state.step)
        # record the RESOLVED strategy name: under cfg.distribution="auto"
        # the carry in DPMRState.strat belongs to whatever the autotuner
        # picked, and a restore must be able to name (and check) it
        extra = {"kind": "dpmr_sparse",
                 "distribution": dpmr.resolve_distribution(self.cfg,
                                                           self.mesh),
                 "topk_frac": self.cfg.topk_frac,
                 "optimizer": self.cfg.optimizer,
                 "num_features": self.cfg.num_features}
        if loader is not None:
            extra["data"] = loader.state_dict()
        self._checkpointer(directory, keep).save(
            step, self.state, block=block, extra=extra)
        return step

    def restore(self, directory: str, step: int | None = None, *,
                loader: ShardedLoader | None = None,
                on_host_change: str = "error") -> dict:
        """Restore state in place (latest step by default); returns the
        checkpoint manifest. Leaves are placed under the engine's current
        shardings, so restoring onto a different mesh re-shards (for a mesh
        with a different shard count, re-pad via runtime/elastic.py).

        If the checkpoint carries a data cursor and a loader is available
        (`loader=` or the engine's attached one), the loader is sought to
        it — training continues on the exact next batch.
        `on_host_change="reassign"` accepts a cursor recorded under a
        different data-plane host count: shard ownership is recomputed for
        the new geometry and the stream resumes at the epoch boundary
        (mirrors the strategy-carry reset on elastic mesh rescale).

        If the checkpoint was written at a DIFFERENT total shard count
        (the cold table's padded length no longer matches this engine's
        mesh), the state is re-padded/re-sharded through
        `runtime/elastic.py::reshard_dpmr_state` instead of being placed
        blind — the elastic-restart path (the strategy carry resets; the
        hot-set geometry, cfg.max_hot, must match)."""
        ck = self._checkpointer(directory, keep=3)
        with compat.set_mesh(self.mesh):
            arrs, manifest = ck.restore_host(step)
            leaves, treedef = jax.tree.flatten(self.state)
            if len(arrs) != len(leaves):
                raise ValueError(
                    f"checkpoint has {len(arrs)} leaves, the engine state "
                    f"{len(leaves)} — not a {manifest['extra'].get('kind')} "
                    "checkpoint for this state structure")
            if [tuple(s) for s in manifest["shapes"]] == \
                    [tuple(l.shape) for l in leaves]:
                # scalar leaves (step) may live uncommitted on one device;
                # device_putting them under that SingleDeviceSharding would
                # COMMIT them there and conflict with the mesh-sharded
                # table in the next jitted step — replicate instead
                from jax.sharding import NamedSharding, PartitionSpec

                rep = NamedSharding(self.mesh, PartitionSpec())
                self.state = jax.tree.unflatten(treedef, [
                    jax.device_put(a, l.sharding
                                   if isinstance(l.sharding, NamedSharding)
                                   else rep)
                    for a, l in zip(arrs, leaves, strict=True)])
            else:
                from repro.runtime.elastic import reshard_dpmr_state

                self.state = reshard_dpmr_state(
                    jax.tree.unflatten(treedef, arrs), self.cfg, self.mesh)
        saved_dist = manifest.get("extra", {}).get("distribution")
        if saved_dist is not None and saved_dist not in list_strategies():
            # a registry KeyError here would name nothing useful; the
            # common culprit is a composition (or other user-registered
            # strategy) from the saving session that this process never
            # re-registered
            raise ValueError(
                f"checkpoint was trained with distribution strategy "
                f"{saved_dist!r}, which is not registered in this "
                "process — register it first (register_strategy / "
                "register_composition, e.g. a session-local composition "
                "does not auto-register on import). Registered: "
                f"{list_strategies()}")
        mine = dpmr.resolve_distribution(self.cfg, self.mesh)
        if saved_dist is not None and saved_dist != mine:
            warnings.warn(
                f"checkpoint was trained with distribution={saved_dist!r} "
                f"but this engine uses {mine!r}; the "
                "persistent strategy carry (DPMRState.strat) may be "
                "meaningless or mis-shaped for the new strategy",
                RuntimeWarning, stacklevel=2)
        saved_frac = manifest.get("extra", {}).get("topk_frac")
        if (mine == "topk_reduce"
                and saved_dist == "topk_reduce"
                and saved_frac is not None
                and saved_frac != self.cfg.topk_frac):
            warnings.warn(
                f"checkpoint carries a topk_reduce residual accumulated at "
                f"topk_frac={saved_frac} but this engine sparsifies at "
                f"{self.cfg.topk_frac}; training stays correct (error "
                "feedback re-injects it) but the first steps flush a "
                "residual sized for the old k",
                RuntimeWarning, stacklevel=2)
        if loader is not None:
            self._loader = loader      # attach even for cursor-less ckpts,
        else:                          # so the NEXT save records a cursor
            loader = self._loader
        data_state = manifest.get("extra", {}).get("data")
        if data_state is not None:
            if loader is not None:
                loader.load_state_dict(data_state,
                                       on_host_change=on_host_change)
            else:
                warnings.warn(
                    "checkpoint carries a data cursor "
                    f"{data_state.get('cursor')} but no loader is attached; "
                    "pass loader= (or seek your loader to this cursor) or "
                    "training will replay already-consumed batches",
                    RuntimeWarning, stacklevel=2)
        return manifest
