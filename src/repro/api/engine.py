"""`DPMREngine` — the typed façade over the DPMR sparse core.

One object owns the compiled step functions (`StepFns`), the sharded
`DPMRState`, host→device batch placement, the optimizer/schedule selection,
and the checkpoint story:

    from repro.api import DPMREngine

    eng = DPMREngine(cfg, mesh, hot_ids=hot)
    eng.fit_sgd(batches)                   # minibatch SGD
    eng.fit(batch_iter_fn)                 # paper-regime full-batch GD
    probs = eng.predict(batch)
    metrics = eng.evaluate(test_batches)
    eng.save("/ckpt/dir"); eng.restore("/ckpt/dir")

Step functions are compiled lazily per global batch size and cached, so one
engine serves training and differently-sized eval batches. The distribution
strategy (`cfg.distribution`) is resolved through the registry in
`repro.api.strategies`.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import DPMRConfig
from repro.core import dpmr, hot_sharding
from repro.core.dpmr import StepFns


def put_batch(batch: dict, mesh) -> dict:
    """Host→device placement: every batch leaf sharded over all mesh axes."""
    axes = tuple(mesh.axis_names)
    sharding = NamedSharding(mesh, P(axes))
    return {k: jax.device_put(jnp.asarray(v), sharding)
            for k, v in batch.items()}


def binary_prf_metrics(predict_fn: Callable[[dict], np.ndarray],
                       test_batches: Iterable[dict]) -> Dict:
    """Fig. 1 metrics: per-class precision/recall/F + macro average.

    `predict_fn(batch) -> probs`; batches must carry "labels". Shared by
    DPMREngine.evaluate and the deprecated sparse_lr.evaluate shim.
    """
    tp = fp = fn_ = tn = 0
    for batch in test_batches:
        pred = (predict_fn(batch) >= 0.5).astype(np.int32)
        y = np.asarray(batch["labels"])
        tp += int(np.sum((pred == 1) & (y == 1)))
        fp += int(np.sum((pred == 1) & (y == 0)))
        fn_ += int(np.sum((pred == 0) & (y == 1)))
        tn += int(np.sum((pred == 0) & (y == 0)))

    def prf(tp, fp, fn):
        p = tp / max(tp + fp, 1)
        r = tp / max(tp + fn, 1)
        f = 2 * p * r / max(p + r, 1e-9)
        return p, r, f

    p1, r1, f1 = prf(tp, fp, fn_)
    p0, r0, f0 = prf(tn, fn_, fp)
    return {
        "precision_pos": p1, "recall_pos": r1, "f_pos": f1,
        "precision_neg": p0, "recall_neg": r0, "f_neg": f0,
        "precision_avg": (p1 + p0) / 2, "recall_avg": (r1 + r0) / 2,
        "f_avg": (f1 + f0) / 2,
    }


def hot_ids_from_corpus(cfg: DPMRConfig, sample_batches: Iterable[dict],
                        mesh) -> jax.Array:
    """initParameters-time frequency statistics -> replicated hot set."""
    f = dpmr.padded_features(cfg, mesh)
    counts = jnp.zeros((f,), jnp.int32)
    for b in sample_batches:
        counts = counts + hot_sharding.feature_counts(
            jnp.asarray(b["ids"]), f)
    return hot_sharding.select_hot(counts, cfg.hot_threshold, cfg.max_hot)


class DPMREngine:
    """Typed façade: state + compiled steps + checkpointing for sparse DPMR.

    Parameters
    ----------
    cfg:         DPMRConfig (features, strategy, optimizer, schedule, ...)
    mesh:        jax Mesh; every device is one DPMR node (samples + params)
    kernel_impl: computeGradients map body ("jnp" | "pallas" |
                 "pallas_interpret")
    cap_factor:  a2a capacity factor (slots per (src,dst) pair = cap_factor
                 x the uniform mean)
    hot_ids:     replicated Zipf-head ids (see `hot_ids_from_corpus`); None
                 disables hot replication
    state:       resume from an existing DPMRState instead of zeros
    """

    def __init__(self, cfg: DPMRConfig, mesh, *, kernel_impl: str = "jnp",
                 cap_factor: float = 4.0, hot_ids=None,
                 state: Optional[dpmr.DPMRState] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.kernel_impl = kernel_impl
        self.cap_factor = cap_factor
        self._fns: Dict[int, StepFns] = {}
        self._schedule = dpmr.make_schedule(cfg)
        with compat.set_mesh(mesh):
            self.state = state if state is not None else dpmr.init_state(
                cfg, mesh, hot_ids)

    # -- step-function compilation cache ------------------------------------

    def step_fns(self, batch_size: int) -> StepFns:
        """Compiled StepFns for a given GLOBAL batch size (cached)."""
        fns = self._fns.pop(batch_size, None)
        if fns is None:
            with compat.set_mesh(self.mesh):
                fns = dpmr.make_step_fns(
                    self.cfg, self.mesh, batch_size,
                    kernel_impl=self.kernel_impl,
                    cap_factor=self.cap_factor)
        self._fns[batch_size] = fns     # move to the end: most recently used
        return fns

    @property
    def fns(self) -> StepFns:
        """StepFns of the most recently used batch size."""
        if not self._fns:
            raise RuntimeError("no step fns compiled yet; run a step or "
                               "call engine.step_fns(batch_size)")
        return next(reversed(self._fns.values()))

    def put_batch(self, batch: dict) -> dict:
        return put_batch(batch, self.mesh)

    def learning_rate(self) -> float:
        """Schedule value at the current step."""
        return float(self._schedule(jnp.asarray(self.state.step)))

    # -- training -----------------------------------------------------------

    def train_step(self, batch: dict) -> Dict:
        """One minibatch update; returns host-side metrics."""
        fns = self.step_fns(len(batch["labels"]))
        with compat.set_mesh(self.mesh):
            self.state, m = fns.train_step(self.state,
                                           self.put_batch(batch))
        return {"loss": float(m["loss"]), "accuracy": float(m["accuracy"]),
                "overflow": int(m["overflow"])}

    def fit_sgd(self, batches: Iterable[dict]) -> List[Dict]:
        """Minibatch SGD (one update per batch); returns the history."""
        history: List[Dict] = []
        for i, batch in enumerate(batches):
            m = self.train_step(batch)
            history.append({"step": i + 1, **m})
        return history

    def fit(self, batch_iter_fn: Callable[[], Iterable[dict]],
            iterations: Optional[int] = None,
            eval_fn: Optional[Callable[["DPMREngine"], Dict]] = None
            ) -> List[Dict]:
        """Full-batch gradient descent: one update per ITERATION over the
        whole corpus (the paper's regime). `batch_iter_fn()` yields the
        training corpus in fixed-size batches each time it is called."""
        iterations = self.cfg.iterations if iterations is None else iterations
        history: List[Dict] = []
        for it in range(iterations):
            acc_cold = jnp.zeros_like(self.state.cold)
            acc_hot = jnp.zeros_like(self.state.hot)
            tot_loss = tot_acc = 0.0
            nb = 0
            with compat.set_mesh(self.mesh):
                for batch in batch_iter_fn():
                    fns = self.step_fns(len(batch["labels"]))
                    gc, gh, m = fns.grad_step(self.state,
                                              self.put_batch(batch))
                    acc_cold = acc_cold + gc
                    acc_hot = acc_hot + gh
                    tot_loss += float(m["loss"])
                    tot_acc += float(m["accuracy"])
                    nb += 1
                self.state = fns.apply_update(
                    self.state, acc_cold / nb, acc_hot / nb,
                    self.learning_rate())
            rec = {"iteration": it + 1, "loss": tot_loss / nb,
                   "accuracy": tot_acc / nb}
            if eval_fn is not None:
                rec.update(eval_fn(self))
            history.append(rec)
        return history

    # -- inference ----------------------------------------------------------

    def predict(self, batch: dict) -> np.ndarray:
        """Algorithm 9: probabilities for a test batch ({ids, vals})."""
        fns = self.step_fns(len(batch["ids"]))
        with compat.set_mesh(self.mesh):
            probs = fns.predict(self.state, self.put_batch(
                {k: batch[k] for k in ("ids", "vals")}))
        return np.asarray(probs)

    def evaluate(self, test_batches: Iterable[dict]) -> Dict:
        """Fig. 1 metrics: per-class precision/recall/F + macro average."""
        return binary_prf_metrics(self.predict, test_batches)

    # -- checkpointing -------------------------------------------------------

    def save(self, directory: str, *, keep: int = 3,
             block: bool = True) -> int:
        """Atomic checkpoint of the sparse state; returns the step saved."""
        step = int(self.state.step)
        Checkpointer(directory, keep=keep).save(
            step, self.state, block=block,
            extra={"kind": "dpmr_sparse",
                   "distribution": self.cfg.distribution,
                   "optimizer": self.cfg.optimizer,
                   "num_features": self.cfg.num_features})
        return step

    def restore(self, directory: str, step: Optional[int] = None) -> Dict:
        """Restore state in place (latest step by default); returns the
        checkpoint manifest. Leaves are placed under the engine's current
        shardings, so restoring onto a different mesh re-shards (for a mesh
        with a different shard count, re-pad via runtime/elastic.py)."""
        with compat.set_mesh(self.mesh):
            self.state, manifest = Checkpointer(directory).restore(
                self.state, step=step)
        return manifest
