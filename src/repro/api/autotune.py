"""Analytic geometry autotuner over the distribution-strategy registry.

Every registered strategy (compositions included) prices itself with a
two-tier `WireBytes(inner, outer)` model that `repro.analysis.audit`
proves against the collectives in its own jaxpr. This module turns those
audited models into a planner: given a `StrategyContext` plus declared
(or measured) per-tier bandwidths, `score_strategies` charges each tier's
bytes at that tier's speed and ranks every candidate by the seconds its
exchange would occupy the wire; `choose_strategy` picks the cheapest
admissible one. `DPMRConfig.distribution = "auto"` routes through it
(`core.dpmr.resolve_distribution`), `launch/dryrun.py --strategies`
prints the ranked table with the winner marked, and
`benchmarks/strategy_autotune.py` pins the production-geometry win as a
regression-gated artifact.

The objective is wire-cost seconds, NOT total bytes: a hierarchical
strategy deliberately spends MORE ICI bytes to spend fewer DCN bytes,
which only reads as a win once each tier is charged at its own speed.

Tie-breaking is deterministic (equal cost falls back to name order), so
the tuned choice is stable across runs — checkpoints record the resolved
name, and the hypothesis suite in tests/test_properties.py holds the
optimality/monotonicity/determinism contract.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.api.strategies import (StrategyContext, WireBytes, get_strategy,
                                  list_strategies)


class WireBandwidth(NamedTuple):
    """Per-tier wire speeds in GB/s.

    Defaults are the repo's planning numbers (ICI ~10x DCN, the ratio the
    mesh-tier split is built around); pass measured values to tune for a
    real fabric.
    """

    inner_gbps: float = 900.0   # ICI, intra-pod
    outer_gbps: float = 90.0    # DCN, cross-pod


class ScoredStrategy(NamedTuple):
    """One ranked candidate: its audited wire model priced on a fabric."""

    name: str
    wire: WireBytes
    cost_s: float     # seconds the exchange occupies the wire
    lossy: bool       # carries error-feedback state on this geometry


def wire_cost(wire: WireBytes, bandwidth: WireBandwidth) -> float:
    """Seconds of wire occupancy: each tier's bytes at that tier's speed."""
    return (wire.inner / (bandwidth.inner_gbps * 1e9)
            + wire.outer / (bandwidth.outer_gbps * 1e9))


def score_strategies(ctx: StrategyContext,
                     bandwidth: WireBandwidth | None = None, *,
                     require_exact: bool = False,
                     strategies: list[str] | None = None
                     ) -> list[ScoredStrategy]:
    """Rank candidates by analytic wire cost on `ctx`, cheapest first.

    `strategies` defaults to the whole registry. `require_exact` drops
    candidates that are lossy ON THIS GEOMETRY (i.e. `init_carry(ctx)` is
    not None — a composition is exact on a single-pod mesh where it
    degenerates to its member). Equal costs break deterministically by
    name.
    """
    bw = bandwidth or WireBandwidth()
    scored = []
    for name in (strategies if strategies is not None else list_strategies()):
        s = get_strategy(name)
        lossy = s.init_carry(ctx) is not None
        if require_exact and lossy:
            continue
        wire = s.bytes_per_device(ctx)
        scored.append(ScoredStrategy(name=name, wire=wire,
                                     cost_s=wire_cost(wire, bw),
                                     lossy=lossy))
    return sorted(scored, key=lambda s: (s.cost_s, s.name))


def choose_strategy(ctx: StrategyContext,
                    bandwidth: WireBandwidth | None = None, *,
                    require_exact: bool = False,
                    strategies: list[str] | None = None) -> str:
    """The cheapest admissible strategy name for `ctx` (see
    `score_strategies` for the ranking contract)."""
    ranked = score_strategies(ctx, bandwidth, require_exact=require_exact,
                              strategies=strategies)
    if not ranked:
        raise ValueError(
            "no admissible strategy to choose from "
            f"(require_exact={require_exact}, candidates="
            f"{strategies if strategies is not None else list_strategies()})")
    return ranked[0].name
