"""Typed public API for the DPMR sparse core.

    from repro.api import DPMREngine, register_strategy, list_strategies

`DPMREngine` is the façade (state + compiled StepFns + batch placement +
checkpointing); the strategy registry makes the parameter-distribution
shuffle a pluggable component — including per-tier compositions
(`ComposedStrategy` / `register_composition`, e.g. `"hier_a2a+topk"`) and
the analytic geometry autotuner (`repro.api.autotune`, reached via
`DPMRConfig.distribution = "auto"`) — and the data plane (`repro.data`,
re-exported here) does the same for the input face:
`fit`/`fit_sgd`/`evaluate` accept a `ShardedLoader` or a registered source
name + spec. The legacy fn-dict surfaces (`core.sparse_lr`, `fns["..."]`
access) were removed after their one-release deprecation — migration table
in CHANGES.md.
"""
from repro.api.autotune import (
    ScoredStrategy,
    WireBandwidth,
    choose_strategy,
    score_strategies,
)
from repro.api.engine import (
    DPMREngine,
    hot_ids_from_corpus,
    put_batch,
)
from repro.api.strategies import (
    AllGatherStrategy,
    AllToAllStrategy,
    ComposedStrategy,
    CompressedReduceStrategy,
    DistributionStrategy,
    HierarchicalA2AStrategy,
    Int8OuterLeg,
    OuterLeg,
    OverlapA2AStrategy,
    PsumScatterStrategy,
    StrategyContext,
    TopKOuterLeg,
    TopKReduceStrategy,
    WireBytes,
    get_strategy,
    list_strategies,
    register_composition,
    register_strategy,
)
from repro.core.dpmr import DPMRState, StepFns, init_state, make_step_fns
from repro.data import (
    Cursor,
    DataSource,
    ShardedLoader,
    get_source,
    list_sources,
    register_source,
    write_file_corpus,
)

__all__ = [
    "AllGatherStrategy", "AllToAllStrategy", "ComposedStrategy",
    "CompressedReduceStrategy", "Cursor", "DPMREngine", "DPMRState",
    "DataSource", "DistributionStrategy", "HierarchicalA2AStrategy",
    "Int8OuterLeg", "OuterLeg", "OverlapA2AStrategy", "PsumScatterStrategy",
    "ScoredStrategy", "ShardedLoader", "StepFns", "StrategyContext",
    "TopKOuterLeg", "TopKReduceStrategy", "WireBandwidth", "WireBytes",
    "choose_strategy", "get_source", "get_strategy", "hot_ids_from_corpus",
    "init_state", "list_sources", "list_strategies", "make_step_fns",
    "put_batch", "register_composition", "register_source",
    "register_strategy", "score_strategies", "write_file_corpus",
]
