"""Typed public API for the DPMR sparse core.

    from repro.api import DPMREngine, register_strategy, list_strategies

`DPMREngine` is the façade (state + compiled StepFns + batch placement +
checkpointing); the strategy registry makes the parameter-distribution
shuffle a pluggable component. The legacy fn-dict surfaces in
`repro.core.api` / `repro.core.sparse_lr` delegate here and will be removed
after one release.
"""
from repro.api.engine import (
    DPMREngine,
    hot_ids_from_corpus,
    put_batch,
)
from repro.api.strategies import (
    AllGatherStrategy,
    AllToAllStrategy,
    DistributionStrategy,
    PsumScatterStrategy,
    StrategyContext,
    get_strategy,
    list_strategies,
    register_strategy,
)
from repro.core.dpmr import DPMRState, StepFns, init_state, make_step_fns

__all__ = [
    "AllGatherStrategy", "AllToAllStrategy", "DPMREngine", "DPMRState",
    "DistributionStrategy", "PsumScatterStrategy", "StepFns",
    "StrategyContext", "get_strategy", "hot_ids_from_corpus", "init_state",
    "list_strategies", "make_step_fns", "put_batch", "register_strategy",
]
