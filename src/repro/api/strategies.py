"""Pluggable parameter-distribution strategies for the DPMR sparse engine.

The paper's distributeParameters / gradient-reduce shuffle is one point in a
design space (its §5 comparison against broadcast-style distribution is the
central efficiency claim). This module makes that axis a first-class,
registry-backed component: a `DistributionStrategy` implements the two
collective-bearing stages of the per-device pipeline, and `core.dpmr` asks
the registry for whichever one `DPMRConfig.distribution` names.

The mesh is two-tier: `ctx.inner_axes` (ICI, fast) and `ctx.outer_axes`
(DCN, ~10x slower; the `pod` axis when present). Every strategy's
`bytes_per_device` wire model is therefore two-tier too — it returns a
`WireBytes(inner, outer)` counting the bytes a device RECEIVES per step,
classified by whether the sender sits in the same inner group (ICI) or in
another outer group (DCN). A device's own chunk never leaves the chip and
is never counted — `repro.analysis.audit` cross-checks every model against
the jaxpr-extracted collectives under exactly this convention.

Built-ins (P = shards, Pi = inner shards, cap = a2a capacity, |F|/P =
block rows per device):

  a2a              the paper's shuffle: route_build + all_to_all of
                   requested rows, reverse all_to_all of per-feature
                   gradient sums. Total 3*(P-1)*cap*4, |F|-independent;
                   the (P-Pi) buckets from other pods cross DCN.
  allgather        the ship-the-table strawman: all_gather the full table,
                   dense scatter-add + psum_scatter reduce.
                   Total ~2*|F|*4, of which the blocks owned by other pods
                   (2*(|F|/P)*(P-Pi)*4) cross DCN.
  psum_scatter     hybrid: sparse a2a shuffle forward, dense psum_scatter
                   reduce. 2*(P-1)*cap*4 + (|F|/P)*(P-1)*4.
  hier_a2a         two-level exchange: each device mirrors its inner-peer
                   blocks across pods (all_gather over `pod`), the sparse
                   all-to-all then runs ONLY inside the fast inner axes,
                   and the reduce crosses DCN once with the already-reduced
                   per-pod partials (psum_scatter of the owner blocks).
                   DCN bytes = 2*(|F|/P)*(Po-1)*4, independent of the batch
                   — strictly below flat a2a's 3*(P-Pi)*cap*4 whenever the
                   per-device table block is smaller than the shuffled
                   request volume (the paper's huge-batch regime).
  compressed_reduce sparse a2a forward; the dense reduce puts int8 on the
                   wire (optim/compression.py block quantization) with
                   error feedback carried in `DPMRState.strat` and
                   persisted by engine save()/restore(). ~4x fewer reduce
                   bytes than psum_scatter at f32.
  topk_reduce      sparse a2a forward; the reverse shuffle sends only the
                   k = ceil(topk_frac*cap) largest-|g| slots per
                   destination as (value, id) pairs, the rest feed a
                   per-device error-feedback residual in `DPMRState.strat`.
                   Reduce bytes drop cap -> 2k on both tiers.
  overlap_a2a      a2a with every exchange split into micro-chunk
                   collectives XLA can dispatch asynchronously and overlap
                   with the step's compute. Bit-identical to a2a; same
                   wire bytes.
  hier_a2a+topk    per-tier composition (`ComposedStrategy`): hier_a2a's
                   exact exchange on ICI, a top-k sparsified reduce on the
                   DCN leg only — k = ceil(topk_frac*(|F|/P)) (value, row)
                   pairs per pod pair, error feedback in `DPMRState.strat`.
  hier_a2a+int8    same composition with the DCN partials crossing as int8
                   + per-block f32 scales (compressed_reduce's scheme on
                   the outer tier only).

All exact strategies produce identical parameters when capacity does not
overflow (tested in tests/test_dpmr.py) — `overlap_a2a` bit-identically so;
`compressed_reduce` / `topk_reduce` track them to within quantization /
sparsification error (convergence parity is benchmarked in
benchmarks/strategy_hierarchy.py and benchmarks/strategy_overlap.py). They
differ in wire bytes per tier and in how capacity-overflowed features
degrade.

Third parties extend the seam with either

    @register_strategy("my_strategy")
    class MyStrategy(DistributionStrategy): ...

or `register_strategy("name", instance)` — the authoring contract (method
semantics, the two-tier wire model, persistent carry state) is documented
in docs/strategies.md with a runnable example.

Every method runs INSIDE shard_map: `cold_loc` is this device's block of the
feature table and collectives run over `ctx.axes` (or a tier subset).
"""
from __future__ import annotations

import copy
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sparse
from repro.kernels import ops
from repro.optim import compression


class WireBytes(NamedTuple):
    """Per-device per-step wire cost, split by mesh tier.

    `inner` travels the fast intra-pod interconnect (ICI); `outer` crosses
    the slow inter-pod network (DCN). `total` is the legacy single number.
    """

    inner: int
    outer: int

    @property
    def total(self) -> int:
        return self.inner + self.outer


class StrategyContext(NamedTuple):
    """Static per-step geometry handed to every strategy method.

    `axes` are ALL mesh axes the pipeline is manual over; they factor into
    `outer_axes` (the DCN-crossing tier, `("pod",)` on multi-pod meshes,
    `()` otherwise) followed by `inner_axes` (everything else, ICI). The
    outer axes are always a LEADING prefix of `axes` (launch.mesh.tier_axes
    enforces this), so the linear device index over `axes` decomposes as
    `outer_index * inner_shards + inner_index`.

    Analytic callers (benchmarks, dry-runs) may leave the axis names empty
    and set only the shard counts; only the collectives need real names.
    """

    axes: tuple[str, ...]    # mesh axis names the pipeline is manual over
    num_shards: int          # P = product of mesh axis sizes
    block_size: int          # rows of the feature table per device
    capacity: int            # per-(src,dst) a2a slots for cold features
    inner_axes: tuple[str, ...] = ()   # fast tier (ICI); () = all of `axes`
    outer_axes: tuple[str, ...] = ()   # slow tier (DCN); () = single tier
    outer_shards: int = 1    # Po = product of outer axis sizes
    topk_frac: float = 0.25  # topk_reduce: kept fraction of the capacity
    #                          slots (k = ceil(topk_frac * capacity));
    #                          threaded from DPMRConfig.topk_frac by
    #                          core.dpmr.make_strategy_context
    kernel_impl: str = "xla"  # lowering of the routing hot path
    #                          (repro.kernels.ops.KERNEL_IMPLS): "xla" =
    #                          the reference jnp chain, "pallas"/"pallas_
    #                          interpret" = the fused kernels. Threaded
    #                          from DPMRConfig.kernel_impl by
    #                          core.dpmr.make_step_fns; strategies consult
    #                          it through kernels.ops dispatchers only, so
    #                          collectives (and the audited wire model)
    #                          are identical across impls.

    @property
    def inner_shards(self) -> int:
        """Pi = devices per pod (over the fast tier)."""
        return self.num_shards // max(self.outer_shards, 1)


class DistributionStrategy:
    """Interface for the distributeParameters / reduce pair of stages.

    `distribute` returns the per-slot cold parameters plus an opaque
    forward-state dict that the engine threads into `reduce`; `overflow`
    must be a scalar int32 in that dict (0 when the strategy cannot drop).

    A strategy may carry persistent per-device state across steps (e.g.
    compression error feedback): override `init_carry` to return its
    zero value — a 1-D f32 array of static length. The engine then stores
    it in `DPMRState.strat` (checkpointed by save()/restore()), passes the
    current value to `reduce` as `fwd["carry"]`, and expects `reduce` to
    return `(grad_cold, new_carry)` instead of the bare gradient.
    """

    name: str = "base"

    def distribute(self, ctx: StrategyContext, cold_loc: jax.Array,
                   cold_ids: jax.Array) -> tuple[jax.Array, dict]:
        raise NotImplementedError

    def reduce(self, ctx: StrategyContext, cold_loc: jax.Array,
               grads_flat: jax.Array, fwd: dict) -> jax.Array:
        raise NotImplementedError

    def init_carry(self, ctx: StrategyContext) -> jax.Array | None:
        """Zero value of the per-device persistent state (None = stateless)."""
        return None

    # two-tier wire-cost model (bytes per device per step); benchmarks,
    # launch/dryrun.py and the scripts/check.sh smoke consume both tiers
    def bytes_per_device(self, ctx: StrategyContext) -> WireBytes:
        raise NotImplementedError


def _owner_base(ctx: StrategyContext) -> jax.Array:
    return jax.lax.axis_index(ctx.axes) * ctx.block_size


def _owner_accumulate(ctx: StrategyContext, req_ids, grads, acc_local,
                      base):
    """The reverse-shuffle scatter-add behind the `kernel_impl` seam:
    `ctx.kernel_impl="xla"` is `sparse.owner_accumulate`'s scatter-add,
    the pallas impls reduce sorted runs with the masked-matmul
    `segment_sum_sorted` combiner first (one owner add per unique
    feature). Dispatch lives in `repro.kernels.ops.owner_accumulate`."""
    return ops.owner_accumulate(req_ids, grads, acc_local, base,
                                impl=ctx.kernel_impl)


def _chunked_all_to_all(x: jax.Array, axes, num_chunks: int) -> jax.Array:
    """`jax.lax.all_to_all(x, axes, 0, 0, tiled=True)` split into micro
    collectives over the capacity axis (axis 1).

    Every (destination-row, capacity-slot) element is routed exactly as the
    monolithic exchange routes it, so the result is bit-identical; what
    changes is the lowering — `num_chunks` independent all-to-alls whose
    async start/done pairs XLA's latency-hiding scheduler can dispatch
    early and overlap with the compute between them, instead of one bulk
    transfer serializing the step.
    """
    cap = x.shape[1]
    n = max(1, min(num_chunks, cap))
    if n == 1:
        return jax.lax.all_to_all(x, axes, 0, 0, tiled=True)
    bounds = [cap * i // n for i in range(n + 1)]
    parts = [jax.lax.all_to_all(x[:, lo:hi], axes, 0, 0, tiled=True)
             for lo, hi in zip(bounds, bounds[1:], strict=False) if hi > lo]
    return jnp.concatenate(parts, axis=1)


def _sparse_distribute(ctx, cold_loc, cold_ids, a2a_fn=None):
    """The paper's Algorithm 4: request shuffle + owner lookup + response.

    `a2a_fn(x)` is the exchange primitive for the two (P, cap) buffers —
    the monolithic tiled all_to_all by default; overlap-aware strategies
    substitute a micro-chunked equivalent."""
    if a2a_fn is None:
        a2a_fn = lambda x: jax.lax.all_to_all(  # noqa: E731
            x, ctx.axes, 0, 0, tiled=True)
    routing = sparse.route_build(cold_ids, ctx.num_shards, ctx.block_size,
                                 ctx.capacity)
    req_recv = a2a_fn(routing.req_ids)
    resp = sparse.owner_apply(req_recv, cold_loc, _owner_base(ctx))
    resp_back = a2a_fn(resp)
    theta_cold = sparse.route_return(routing, resp_back)
    return theta_cold, {"routing": routing, "req_recv": req_recv,
                        "cold_ids": cold_ids, "overflow": routing.overflow}


def _dense_accumulate(ctx, cold_loc, grads_flat, cold_ids):
    """Local dense accumulation: the (F,) per-device gradient vector."""
    f = cold_loc.shape[0] * ctx.num_shards
    return jnp.zeros((f,), jnp.float32).at[
        jnp.where(cold_ids >= 0, cold_ids, f)
    ].add(jnp.where(cold_ids >= 0, grads_flat, 0.0), mode="drop")


def _dense_reduce(ctx, cold_loc, grads_flat, cold_ids):
    """Dense accumulate + psum_scatter: every device folds its gradients
    into a full-length vector; one collective delivers owner blocks."""
    gfull = _dense_accumulate(ctx, cold_loc, grads_flat, cold_ids)
    return jax.lax.psum_scatter(gfull, ctx.axes, scatter_dimension=0,
                                tiled=True)


class AllToAllStrategy(DistributionStrategy):
    """Paper-faithful DPMR shuffle in both directions."""

    name = "a2a"

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        send = sparse.combine_grads(fwd["routing"], grads_flat)
        recv = jax.lax.all_to_all(send, ctx.axes, 0, 0, tiled=True)
        return _owner_accumulate(ctx, fwd["req_recv"], recv,
                                 jnp.zeros_like(cold_loc),
                                 _owner_base(ctx))

    def bytes_per_device(self, ctx):
        # 3 (P, cap) f32 buffers (requests, responses, grad sums); a
        # device RECEIVES the (Pi-1) same-pod buckets over ICI and the
        # (P-Pi) buckets addressed from other pods over DCN — its own
        # bucket never leaves the chip
        pi = ctx.inner_shards
        outer = 3 * (ctx.num_shards - pi) * ctx.capacity * 4
        return WireBytes(inner=3 * (pi - 1) * ctx.capacity * 4, outer=outer)


class AllGatherStrategy(DistributionStrategy):
    """Ship-the-table baseline (the paper's comparison point)."""

    name = "allgather"

    def distribute(self, ctx, cold_loc, cold_ids):
        table = jax.lax.all_gather(cold_loc, ctx.axes, tiled=True)
        theta_cold = jnp.where(cold_ids >= 0,
                               table[jnp.clip(cold_ids, 0)], 0.0)
        return theta_cold, {"cold_ids": cold_ids,
                            "overflow": jnp.zeros((), jnp.int32)}

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        return _dense_reduce(ctx, cold_loc, grads_flat, fwd["cold_ids"])

    def bytes_per_device(self, ctx):
        # forward ring all_gather + reduce psum_scatter: every device
        # receives the (P-1) remote blocks of |F|/P rows; the (P-Pi)
        # blocks owned by other pods cross DCN
        pi = ctx.inner_shards
        inner = 2 * ctx.block_size * (pi - 1) * 4
        outer = 2 * ctx.block_size * (ctx.num_shards - pi) * 4
        return WireBytes(inner=inner, outer=outer)


class PsumScatterStrategy(DistributionStrategy):
    """Hybrid: sparse shuffle forward, dense psum_scatter reduce.

    Keeps the forward wire cost |F|-independent while collapsing the reduce
    into one fused collective — attractive when the backward shuffle (not
    the lookup) is the bottleneck and a transient (|F|,) accumulation
    buffer per device is affordable.
    """

    name = "psum_scatter"

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        return _dense_reduce(ctx, cold_loc, grads_flat, fwd["cold_ids"])

    def bytes_per_device(self, ctx):
        pi = ctx.inner_shards
        po_cross = ctx.num_shards - pi
        inner = (2 * (pi - 1) * ctx.capacity * 4
                 + ctx.block_size * (pi - 1) * 4)
        outer = (2 * po_cross * ctx.capacity * 4
                 + ctx.block_size * po_cross * 4)
        return WireBytes(inner=inner, outer=outer)


def _hier_remap(cold_ids: jax.Array, po: int, pi: int,
                block: int) -> jax.Array:
    """Bijection global id -> (inner_owner, mirror_row) contiguous space.

    Row r is owned by device d = r // block with pod q = d // Pi and inner
    index i = d % Pi. After the pod-axis all_gather, device (*, i) holds a
    mirror of all pods' i-blocks, laid out pod-major; relabelling
    r' = i * (Po*block) + q*block + (r % block) makes mirror ownership
    contiguous-block again (block size Po*block over Pi owners), so the
    unmodified routing kernels drive the inner-only exchange.
    """
    q = cold_ids // (pi * block)
    inner_owner = (cold_ids // block) % pi
    off = cold_ids % block
    remapped = inner_owner * (po * block) + q * block + off
    return jnp.where(cold_ids >= 0, remapped, -1)


class HierarchicalA2AStrategy(DistributionStrategy):
    """Two-level exchange over the (pod, ICI) tiers.

    Forward: all_gather over `outer_axes` mirrors, on every device, the
    table blocks of its inner-peer devices in every pod (Po blocks); the
    sparse request/response all-to-all then runs ONLY over `inner_axes`,
    against the mirror, with ids relabelled by `_hier_remap`. Reduce: the
    reverse inner shuffle accumulates per-feature sums into the mirror
    layout, then ONE psum_scatter over `outer_axes` crosses DCN carrying
    the already-reduced per-pod partials and lands each owner's block.

    With a single pod (Po == 1) this is bit-identical to `a2a`. The inner
    capacity is Po x the flat capacity (requests concentrate on Pi owners
    instead of P), so overflow behaviour matches `a2a` at equal headroom.
    """

    name = "hier_a2a"

    def _inner_capacity(self, ctx, n):
        return int(min(n, ctx.capacity * ctx.outer_shards))

    def distribute(self, ctx, cold_loc, cold_ids):
        po, pi = ctx.outer_shards, ctx.inner_shards
        if po == 1:
            return _sparse_distribute(ctx, cold_loc, cold_ids)
        block = ctx.block_size
        mirror = jax.lax.all_gather(cold_loc, ctx.outer_axes,
                                    tiled=True)            # (Po*block,)
        rem = _hier_remap(cold_ids, po, pi, block)
        if pi == 1:
            # one device per pod: the mirror is the whole table, look up
            # locally; DCN still only carries the dense block exchanges
            theta_cold = jnp.where(cold_ids >= 0,
                                   mirror[jnp.clip(rem, 0)], 0.0)
            return theta_cold, {"cold_ids": cold_ids, "rem_ids": rem,
                                "overflow": jnp.zeros((), jnp.int32)}
        cap_i = self._inner_capacity(ctx, cold_ids.shape[0])
        routing = sparse.route_build(rem, pi, po * block, cap_i)
        req_recv = jax.lax.all_to_all(routing.req_ids, ctx.inner_axes,
                                      0, 0, tiled=True)
        base = jax.lax.axis_index(ctx.inner_axes) * (po * block)
        resp = sparse.owner_apply(req_recv, mirror, base)
        resp_back = jax.lax.all_to_all(resp, ctx.inner_axes, 0, 0,
                                       tiled=True)
        theta_cold = sparse.route_return(routing, resp_back)
        return theta_cold, {"routing": routing, "req_recv": req_recv,
                            "cold_ids": cold_ids,
                            "overflow": routing.overflow}

    def _mirror_accumulate(self, ctx, cold_loc, grads_flat, fwd):
        """Inner-tier gradient reduce up to (not including) the DCN leg.

        Returns the (Po*block,) mirror accumulator whose segment q holds
        this pod's partial sums for pod q's owner block — everything the
        strategy does before the single outer-tier collective. This is the
        composition seam: `ComposedStrategy` swaps the psum_scatter that
        follows for a lossy outer leg while reusing this inner exchange.
        Requires Po > 1 (with one pod there is no mirror layout).
        """
        po, pi = ctx.outer_shards, ctx.inner_shards
        block = ctx.block_size
        if pi == 1:
            rem = fwd["rem_ids"]
            f_mirror = po * block
            return jnp.zeros((f_mirror,), jnp.float32).at[
                jnp.where(rem >= 0, rem, f_mirror)
            ].add(jnp.where(rem >= 0, grads_flat, 0.0), mode="drop")
        send = sparse.combine_grads(fwd["routing"], grads_flat)
        recv = jax.lax.all_to_all(send, ctx.inner_axes, 0, 0,
                                  tiled=True)
        base = jax.lax.axis_index(ctx.inner_axes) * (po * block)
        return _owner_accumulate(
            ctx, fwd["req_recv"], recv,
            jnp.zeros((po * block,), grads_flat.dtype), base)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        po = ctx.outer_shards
        if po == 1:
            send = sparse.combine_grads(fwd["routing"], grads_flat)
            recv = jax.lax.all_to_all(send, ctx.axes, 0, 0, tiled=True)
            return _owner_accumulate(ctx, fwd["req_recv"], recv,
                                     jnp.zeros_like(cold_loc),
                                     _owner_base(ctx))
        mirror_acc = self._mirror_accumulate(ctx, cold_loc, grads_flat, fwd)
        # per-pod partials cross DCN exactly once: segment q of the mirror
        # accumulator is pod q's owner block, summed across pods
        return jax.lax.psum_scatter(mirror_acc, ctx.outer_axes,
                                    scatter_dimension=0, tiled=True)

    def bytes_per_device(self, ctx):
        po, pi = ctx.outer_shards, ctx.inner_shards
        # inner: the full sparse shuffle at Po-scaled capacity (all ICI),
        # received from the (Pi-1) inner peers
        inner = 3 * (pi - 1) * (ctx.capacity * po) * 4
        # outer: forward pod all_gather of the local block + reduce
        # psum_scatter of per-pod partials, both ring over Po
        outer = 2 * ctx.block_size * (po - 1) * 4
        return WireBytes(inner=inner, outer=outer)


class CompressedReduceStrategy(DistributionStrategy):
    """Sparse forward + int8 block-quantized dense reduce with error
    feedback (the optim/compression.py scheme on the strategy seam).

    Reduce path: the (F,) per-device gradient vector is compensated with
    the carried error state, block-quantized (`optim.compression.quantize`,
    one f32 scale per `compression.BLOCK` values), and exchanged as int8 by
    destination segment (all_to_all); receivers dequantize and sum their
    own block. The residual `(g + err) - dequant(q)` becomes the new carry,
    so quantization error is re-injected next step (EF-SGD / 1-bit Adam
    lineage) and SGD/Adagrad convergence tracks the exact strategies.

    The carry is per-device and |F|-sized — the engine persists it in
    `DPMRState.strat` and it rides through save()/restore() so a resumed
    run continues bit-identically. On the full-batch accumulation path
    the engine freezes the carry (`fwd["accumulate"]`), so the reduce
    falls back to the exact dense path there — quantizing against a
    frozen residual would re-inject it once per accumulated batch.
    """

    name = "compressed_reduce"

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids)

    def init_carry(self, ctx):
        return jnp.zeros((ctx.num_shards * ctx.block_size,), jnp.float32)

    def _padded_block(self, ctx) -> int:
        qb = compression.BLOCK
        return -(-ctx.block_size // qb) * qb

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        if fwd.get("accumulate", False):
            # full-batch accumulation path (engine grad_step): the carry
            # is frozen there, so quantizing against it would re-inject a
            # restored residual once per accumulated batch instead of
            # once. Use the exact dense reduce and leave the residual
            # untouched (same discipline as topk_reduce).
            return (_dense_reduce(ctx, cold_loc, grads_flat,
                                  fwd["cold_ids"]), fwd["carry"])
        p = ctx.num_shards
        block = ctx.block_size
        qb = compression.BLOCK
        bp = self._padded_block(ctx)
        gfull = _dense_accumulate(ctx, cold_loc, grads_flat,
                                  fwd["cold_ids"])
        comp = gfull + fwd["carry"]                        # error feedback
        seg = jnp.pad(comp.reshape(p, block), ((0, 0), (0, bp - block)))
        q, scale = compression.quantize(seg.reshape(-1))   # (p*bp/qb, qb)
        new_carry = comp - compression.dequantize(
            q, scale, p * bp).reshape(p, bp)[:, :block].reshape(-1)
        # int8 on the wire: exchange by destination segment, dequantize and
        # sum the received contributions to this device's block
        q_recv = jax.lax.all_to_all(q.reshape(p, bp), ctx.axes, 0, 0,
                                    tiled=True)            # (p, bp) int8
        s_recv = jax.lax.all_to_all(scale.reshape(p, bp // qb), ctx.axes,
                                    0, 0, tiled=True)      # (p, bp/qb) f32
        deq = (q_recv.astype(jnp.float32).reshape(p, bp // qb, qb)
               * s_recv[..., None])
        grad = deq.reshape(p, bp)[:, :block].sum(axis=0)
        return grad, new_carry

    def bytes_per_device(self, ctx):
        pi = ctx.inner_shards
        po_cross = ctx.num_shards - pi
        bp = self._padded_block(ctx)
        per_peer = bp + (bp // compression.BLOCK) * 4      # int8 + scales
        inner = 2 * (pi - 1) * ctx.capacity * 4 + (pi - 1) * per_peer
        outer = 2 * po_cross * ctx.capacity * 4 + po_cross * per_peer
        return WireBytes(inner=inner, outer=outer)


class TopKReduceStrategy(DistributionStrategy):
    """Sparse forward + top-k sparsified reverse shuffle with per-device
    error feedback (gradient sparsification on the strategy seam).

    Forward is the paper's shuffle unchanged. On the reduce side each
    device combines its per-feature gradient sums into the (P, cap) send
    buffer, compensates every slot with the carried residual of that slot's
    FEATURE (`carry[feature_id]`), and then sends, per destination owner,
    only the k = ceil(topk_frac * cap) largest-magnitude slots — as (value
    f32, global id int32) pairs, so the wire carries k·P pairs instead of
    cap·P f32 slots. Owners scatter-add the received pairs exactly like
    `a2a` does. Slots that lost the top-k race bank their compensated
    gradient in the residual (`new_carry[feature] = compensated`); selected
    slots reset theirs to zero — EF-SGD lineage, so dropped coordinates are
    re-injected when the feature next appears and SGD/Adagrad convergence
    tracks the exact strategies (benchmarks/strategy_overlap.py sweeps
    loss-vs-k).

    The carry is per-device and |F|-sized, lives in `DPMRState.strat`,
    rides through `engine.save()`/`restore()` bit-exactly, and is reset to
    zeros by `runtime/elastic.py` resharding (a residual is per-device
    state, meaningless under a different shard count). `topk_frac=1.0`
    keeps every slot and the residual stays identically zero.

    Error feedback is only sound where the carry ADVANCES — the per-step
    train_step path. On the full-batch accumulation path the engine
    freezes the carry (`fwd["accumulate"]`, see `core.dpmr`), so this
    strategy detects it and runs the exact a2a reverse shuffle instead:
    fit() gets exact epoch gradients, fit_sgd() gets the sparsified wire.
    """

    name = "topk_reduce"

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids)

    def init_carry(self, ctx):
        return jnp.zeros((ctx.num_shards * ctx.block_size,), jnp.float32)

    def _k(self, ctx) -> int:
        return compression.topk_count(ctx.capacity, ctx.topk_frac)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        if fwd.get("accumulate", False):
            # full-batch accumulation path (engine grad_step): the carry
            # is frozen there — many grad_steps feed ONE update — so
            # sparsifying would permanently drop (1 - k/cap) of the epoch
            # gradient and re-inject any restored residual once per
            # accumulated batch instead of once. Fall back to the exact
            # reverse shuffle and leave the carry untouched; the top-k
            # wire savings apply to the per-step (SGD) path only.
            send = sparse.combine_grads(fwd["routing"], grads_flat)
            recv = jax.lax.all_to_all(send, ctx.axes, 0, 0, tiled=True)
            grad = _owner_accumulate(ctx, fwd["req_recv"], recv,
                                     jnp.zeros_like(cold_loc),
                                     _owner_base(ctx))
            return grad, fwd["carry"]
        f = ctx.num_shards * ctx.block_size
        k = self._k(ctx)
        send = sparse.combine_grads(fwd["routing"], grads_flat)  # (P, cap)
        ids = fwd["routing"].req_ids                             # (P, cap)
        valid = ids >= 0
        # fused compensate + rank-by-|magnitude| + pack: every live slot is
        # compensated with the residual its feature banked the last time it
        # lost the top-k race, each destination row keeps its k
        # largest-|comp| live slots, and losers bank their compensated
        # value as the new residual — one kernels.ops.select_pack call
        # (`kernel_impl="xla"` runs the original five-op chain, see
        # kernels/ref.py:select_pack_ref; the Pallas kernel is bit-exact)
        carry_slots = fwd["carry"][jnp.clip(ids, 0, f - 1)]
        vals_k, ids_k, resid = ops.select_pack(send, ids, carry_slots,
                                               k=k, impl=ctx.kernel_impl)
        # residual scatter: selected features flushed to zero, losers bank
        # their compensated slot (feature ids are unique per device, so a
        # plain scatter-set is race-free; absent features keep theirs, and
        # invalid slots are dropped)
        new_carry = fwd["carry"].at[
            jnp.where(valid, ids, f).reshape(-1)
        ].set(resid.reshape(-1), mode="drop")
        v_recv = jax.lax.all_to_all(vals_k, ctx.axes, 0, 0, tiled=True)
        i_recv = jax.lax.all_to_all(ids_k, ctx.axes, 0, 0, tiled=True)
        grad = _owner_accumulate(ctx, i_recv, v_recv,
                                 jnp.zeros_like(cold_loc),
                                 _owner_base(ctx))
        return grad, new_carry

    def bytes_per_device(self, ctx):
        # forward: the 2 (P, cap) f32 request/response buffers of a2a;
        # reduce: k of cap slots per peer, each an (f32 value, int32 id)
        # pair — the k/cap reduction lands on BOTH tiers
        pi = ctx.inner_shards
        po_cross = ctx.num_shards - pi
        k = self._k(ctx)
        inner = 2 * (pi - 1) * ctx.capacity * 4 + (pi - 1) * k * 8
        outer = 2 * po_cross * ctx.capacity * 4 + po_cross * k * 8
        return WireBytes(inner=inner, outer=outer)


class OverlapA2AStrategy(AllToAllStrategy):
    """Overlap-aware `a2a`: the same exchanges, lowered as micro-chunks.

    Every (P, cap) all-to-all of the paper's shuffle is split into
    `num_chunks` independent collectives over capacity-slot ranges
    (`_chunked_all_to_all`). Element routing is untouched, so parameters
    and gradients are BIT-IDENTICAL to `a2a` on any mesh — only the
    schedule differs: XLA lowers each micro-chunk to its own async
    start/done pair, letting the latency-hiding scheduler dispatch the
    next chunk (and the reverse shuffle of already-landed gradient
    chunks) while the inference matmul of the step still runs, instead of
    serializing one bulk transfer against the compute. Wire bytes equal
    `a2a` (inherited model); what the strategy buys is overlap, measured
    by benchmarks/strategy_overlap.py.
    """

    name = "overlap_a2a"
    num_chunks = 4      # micro-chunks per exchange; capacity-bounded

    def _a2a(self, ctx, x):
        return _chunked_all_to_all(x, ctx.axes, self.num_chunks)

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids,
                                  a2a_fn=lambda x: self._a2a(ctx, x))

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        send = sparse.combine_grads(fwd["routing"], grads_flat)
        recv = self._a2a(ctx, send)
        return _owner_accumulate(ctx, fwd["req_recv"], recv,
                                 jnp.zeros_like(cold_loc),
                                 _owner_base(ctx))


class OuterLeg:
    """The DCN half of a per-tier composition.

    A leg replaces the single outer-tier collective of a hierarchical
    strategy's reduce — it receives the (Po*block,) mirror accumulator
    (segment q = this pod's partials for pod q's owner block) and must
    deliver this device's (block,) owner gradient by exchanging ONLY over
    `ctx.outer_axes`. Legs may keep an error-feedback residual: declare
    its static length via `carry_len` (0 = stateless) and advance it in
    `reduce_outer`; `ComposedStrategy` namespaces it into the composed
    carry that the engine persists in `DPMRState.strat`.
    """

    name: str = "leg"

    def carry_len(self, ctx: StrategyContext) -> int:
        """Static residual length on this geometry (0 = no carry)."""
        return 0

    def reduce_outer(self, ctx: StrategyContext, mirror_acc: jax.Array,
                     carry: jax.Array) -> tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def reduce_bytes(self, ctx: StrategyContext) -> int:
        """DCN bytes a device receives on the reduce leg (Po > 1)."""
        raise NotImplementedError


class TopKOuterLeg(OuterLeg):
    """Top-k sparsified DCN reduce: each pod sends, per destination pod,
    only the k = ceil(topk_frac * block) largest-|g| rows of its partial
    block as (value f32, row int32) pairs; losers bank an error-feedback
    residual over the (Po*block,) mirror layout, re-injected when the row
    next carries gradient mass (same EF-SGD lineage as `topk_reduce`, but
    applied AFTER the exact inner exchange, so only the cheap-to-compress
    cross-pod partials are sparsified).
    """

    name = "topk"

    def _k(self, ctx) -> int:
        return compression.topk_count(ctx.block_size, ctx.topk_frac)

    def carry_len(self, ctx):
        return ctx.outer_shards * ctx.block_size

    def reduce_outer(self, ctx, mirror_acc, carry):
        po, block = ctx.outer_shards, ctx.block_size
        k = self._k(ctx)
        comp = (mirror_acc + carry).reshape(po, block)   # error feedback
        top_idx, top_mask = compression.topk_select(jnp.abs(comp), k)
        vals_k = jnp.take_along_axis(comp, top_idx, axis=1)   # (Po, k)
        ids_k = top_idx.astype(jnp.int32)                # within-block rows
        new_carry = jnp.where(top_mask, 0.0, comp).reshape(-1)
        v_recv = jax.lax.all_to_all(vals_k, ctx.outer_axes, 0, 0,
                                    tiled=True)
        i_recv = jax.lax.all_to_all(ids_k, ctx.outer_axes, 0, 0,
                                    tiled=True)
        grad = jnp.zeros((block,), jnp.float32).at[
            i_recv.reshape(-1)
        ].add(v_recv.reshape(-1))
        return grad, new_carry

    def reduce_bytes(self, ctx):
        # k (f32 value, int32 row) pairs from each of the (Po-1) other pods
        return (ctx.outer_shards - 1) * self._k(ctx) * 8


class Int8OuterLeg(OuterLeg):
    """Int8 block-quantized DCN reduce: the per-pod partial blocks cross
    the slow tier as int8 + per-`compression.BLOCK` f32 scales (the
    `compressed_reduce` scheme, applied to the outer tier only), with the
    quantization residual banked as an error-feedback carry over the
    (Po*block,) mirror layout.
    """

    name = "int8"

    def _padded_block(self, ctx) -> int:
        qb = compression.BLOCK
        return -(-ctx.block_size // qb) * qb

    def carry_len(self, ctx):
        return ctx.outer_shards * ctx.block_size

    def reduce_outer(self, ctx, mirror_acc, carry):
        po, block = ctx.outer_shards, ctx.block_size
        qb = compression.BLOCK
        bp = self._padded_block(ctx)
        comp = mirror_acc + carry                        # error feedback
        seg = jnp.pad(comp.reshape(po, block), ((0, 0), (0, bp - block)))
        q, scale = compression.quantize(seg.reshape(-1))
        new_carry = comp - compression.dequantize(
            q, scale, po * bp).reshape(po, bp)[:, :block].reshape(-1)
        q_recv = jax.lax.all_to_all(q.reshape(po, bp), ctx.outer_axes,
                                    0, 0, tiled=True)    # (Po, bp) int8
        s_recv = jax.lax.all_to_all(scale.reshape(po, bp // qb),
                                    ctx.outer_axes, 0, 0, tiled=True)
        deq = (q_recv.astype(jnp.float32).reshape(po, bp // qb, qb)
               * s_recv[..., None])
        grad = deq.reshape(po, bp)[:, :block].sum(axis=0)
        return grad, new_carry

    def reduce_bytes(self, ctx):
        bp = self._padded_block(ctx)
        per_peer = bp + (bp // compression.BLOCK) * 4    # int8 + scales
        return (ctx.outer_shards - 1) * per_peer


class ComposedStrategy(DistributionStrategy):
    """Per-tier composition: a hierarchical member's exact exchange on the
    fast inner tier (ICI), an `OuterLeg`'s lossy reduce on the slow outer
    tier (DCN).

    The cut point is the member's `_mirror_accumulate` seam: forward and
    the inner gradient shuffle are the member's own (exact), and only the
    single DCN crossing of the reduce is replaced by the leg. With one pod
    (Po == 1) the composition degenerates to the member exactly — it is
    then stateless and bit-identical. Carries are namespaced per member by
    `carry_layout`; on the full-batch accumulation path the composition
    falls back to the member's exact reduce with the carry frozen (the
    same discipline every lossy built-in follows).
    """

    def __init__(self, inner: DistributionStrategy, leg: OuterLeg):
        self.inner = inner
        self.leg = leg
        self.name = f"{inner.name}+{leg.name}"

    def carry_layout(self, ctx) -> list[tuple[str, int]]:
        """Namespaced `(member_name, length)` segments of the composed
        carry, in `DPMRState.strat` order. Only stateful members appear;
        today that is at most the outer leg (`register_composition`
        requires a stateless inner member)."""
        n = self.leg.carry_len(ctx) if ctx.outer_shards > 1 else 0
        return [(self.leg.name, n)] if n else []

    def distribute(self, ctx, cold_loc, cold_ids):
        return self.inner.distribute(ctx, cold_loc, cold_ids)

    def init_carry(self, ctx):
        total = sum(n for _, n in self.carry_layout(ctx))
        if total == 0:
            return None
        return jnp.zeros((total,), jnp.float32)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        if ctx.outer_shards == 1:
            # single tier: the member IS the composition (stateless here)
            return self.inner.reduce(ctx, cold_loc, grads_flat, fwd)
        if fwd.get("accumulate", False):
            # full-batch accumulation: the carry is frozen, so sparsifying
            # or quantizing the DCN leg would drop epoch-gradient mass /
            # re-inject a restored residual once per accumulated batch.
            # Run the member's exact reduce and pass the carry through.
            return (self.inner.reduce(ctx, cold_loc, grads_flat, fwd),
                    fwd["carry"])
        mirror_acc = self.inner._mirror_accumulate(ctx, cold_loc,
                                                   grads_flat, fwd)
        return self.leg.reduce_outer(ctx, mirror_acc, fwd["carry"])

    def bytes_per_device(self, ctx):
        member = self.inner.bytes_per_device(ctx)
        po = ctx.outer_shards
        if po == 1:
            return member
        # inner tier is the member's own (exact) exchange; outer = the
        # forward pod all_gather of the local block + the leg's reduce
        outer = ctx.block_size * (po - 1) * 4 + self.leg.reduce_bytes(ctx)
        return WireBytes(inner=member.inner, outer=outer)


_REGISTRY: dict[str, DistributionStrategy] = {}


def register_strategy(name: str, strategy: DistributionStrategy = None):
    """Register a strategy instance, or use as a class decorator:

        @register_strategy("mine")
        class Mine(DistributionStrategy): ...
    """
    if strategy is not None:
        # shallow-copy so aliasing an existing instance doesn't rename it
        inst = copy.copy(strategy)
        inst.name = name
        _REGISTRY[name] = inst
        return inst

    def _decorate(cls):
        inst = cls() if isinstance(cls, type) else cls
        inst.name = name
        _REGISTRY[name] = inst
        return cls

    return _decorate


def get_strategy(name: str) -> DistributionStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown distribution strategy {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def list_strategies() -> list[str]:
    return sorted(_REGISTRY)


def register_composition(inner_name: str, leg: OuterLeg,
                         name: str | None = None) -> ComposedStrategy:
    """Register `ComposedStrategy(get_strategy(inner_name), leg)` under
    `"<inner>+<leg>"` (or `name`). The inner member must expose the
    `_mirror_accumulate` seam (hierarchical reduce split at the DCN
    crossing) and must be stateless — its own carry would have to be
    namespaced alongside the leg's, which no member needs today.
    """
    inner = get_strategy(inner_name)
    if not hasattr(inner, "_mirror_accumulate"):
        raise TypeError(
            f"strategy {inner_name!r} has no _mirror_accumulate seam; "
            "only hierarchical strategies whose reduce isolates the DCN "
            "crossing can take a composed outer leg")
    composed = ComposedStrategy(inner, leg)
    register_strategy(name or composed.name, composed)
    return composed


register_strategy("a2a", AllToAllStrategy())
register_strategy("allgather", AllGatherStrategy())
register_strategy("psum_scatter", PsumScatterStrategy())
register_strategy("hier_a2a", HierarchicalA2AStrategy())
register_strategy("compressed_reduce", CompressedReduceStrategy())
register_strategy("topk_reduce", TopKReduceStrategy())
register_strategy("overlap_a2a", OverlapA2AStrategy())
register_composition("hier_a2a", TopKOuterLeg())
register_composition("hier_a2a", Int8OuterLeg())
