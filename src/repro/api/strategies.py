"""Pluggable parameter-distribution strategies for the DPMR sparse engine.

The paper's distributeParameters / gradient-reduce shuffle is one point in a
design space (its §5 comparison against broadcast-style distribution is the
central efficiency claim). This module makes that axis a first-class,
registry-backed component: a `DistributionStrategy` implements the two
collective-bearing stages of the per-device pipeline, and `core.dpmr` asks
the registry for whichever one `DPMRConfig.distribution` names.

Built-ins (bytes/device counts BOTH the forward and the reduce collective;
the seed's benchmark counted only the forward table movement for allgather):

  a2a           the paper's shuffle: route_build + all_to_all of requested
                rows, reverse all_to_all of per-feature gradient sums.
                Bytes/device = 3 * P * cap * 4, independent of |F|.
  allgather     the ship-the-table strawman: all_gather the full table for
                lookups, dense scatter-add + psum_scatter for the reduce.
                Bytes/device ~ 2 * |F| * 4.
  psum_scatter  hybrid: sparse a2a shuffle forward (cheap lookups), dense
                psum_scatter reduce (one fused collective, no reverse
                shuffle). Bytes/device ~ 2 * P * cap * 4 + |F| * 4.

All strategies produce identical parameters when capacity does not overflow
(tested in tests/test_dpmr.py); they differ only in wire bytes and in how
capacity-overflowed features degrade (a2a drops their gradients, the dense
reducers keep them).

Third parties extend the seam with either

    @register_strategy("my_strategy")
    class MyStrategy(DistributionStrategy): ...

or `register_strategy("name", instance)`.

Every method runs INSIDE shard_map: `cold_loc` is this device's block of the
feature table and collectives run over `ctx.axes`.
"""
from __future__ import annotations

import copy
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparse


class StrategyContext(NamedTuple):
    """Static per-step geometry handed to every strategy method."""

    axes: Tuple[str, ...]    # mesh axis names the pipeline is manual over
    num_shards: int          # P = product of mesh axis sizes
    block_size: int          # rows of the feature table per device
    capacity: int            # per-(src,dst) a2a slots for cold features


class DistributionStrategy:
    """Interface for the distributeParameters / reduce pair of stages.

    `distribute` returns the per-slot cold parameters plus an opaque
    forward-state dict that the engine threads into `reduce`; `overflow`
    must be a scalar int32 in that dict (0 when the strategy cannot drop).
    """

    name: str = "base"

    def distribute(self, ctx: StrategyContext, cold_loc: jax.Array,
                   cold_ids: jax.Array) -> Tuple[jax.Array, dict]:
        raise NotImplementedError

    def reduce(self, ctx: StrategyContext, cold_loc: jax.Array,
               grads_flat: jax.Array, fwd: dict) -> jax.Array:
        raise NotImplementedError

    # wire-cost model (bytes per device per step), used by the benchmarks
    def bytes_per_device(self, ctx: StrategyContext) -> int:
        raise NotImplementedError


def _owner_base(ctx: StrategyContext) -> jax.Array:
    return jax.lax.axis_index(ctx.axes) * ctx.block_size


def _sparse_distribute(ctx, cold_loc, cold_ids):
    """The paper's Algorithm 4: request shuffle + owner lookup + response."""
    routing = sparse.route_build(cold_ids, ctx.num_shards, ctx.block_size,
                                 ctx.capacity)
    req_recv = jax.lax.all_to_all(routing.req_ids, ctx.axes, 0, 0,
                                  tiled=True)
    resp = sparse.owner_apply(req_recv, cold_loc, _owner_base(ctx))
    resp_back = jax.lax.all_to_all(resp, ctx.axes, 0, 0, tiled=True)
    theta_cold = sparse.route_return(routing, resp_back)
    return theta_cold, {"routing": routing, "req_recv": req_recv,
                        "cold_ids": cold_ids, "overflow": routing.overflow}


def _dense_reduce(ctx, cold_loc, grads_flat, cold_ids):
    """Dense accumulate + psum_scatter: every device folds its gradients
    into a full-length vector; one collective delivers owner blocks."""
    f = cold_loc.shape[0] * ctx.num_shards
    gfull = jnp.zeros((f,), jnp.float32).at[
        jnp.where(cold_ids >= 0, cold_ids, f)
    ].add(jnp.where(cold_ids >= 0, grads_flat, 0.0), mode="drop")
    return jax.lax.psum_scatter(gfull, ctx.axes, scatter_dimension=0,
                                tiled=True)


class AllToAllStrategy(DistributionStrategy):
    """Paper-faithful DPMR shuffle in both directions."""

    name = "a2a"

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        send = sparse.combine_grads(fwd["routing"], grads_flat)
        recv = jax.lax.all_to_all(send, ctx.axes, 0, 0, tiled=True)
        return sparse.owner_accumulate(fwd["req_recv"], recv,
                                       jnp.zeros_like(cold_loc),
                                       _owner_base(ctx))

    def bytes_per_device(self, ctx):
        return 3 * ctx.num_shards * ctx.capacity * 4


class AllGatherStrategy(DistributionStrategy):
    """Ship-the-table baseline (the paper's comparison point)."""

    name = "allgather"

    def distribute(self, ctx, cold_loc, cold_ids):
        table = jax.lax.all_gather(cold_loc, ctx.axes, tiled=True)
        theta_cold = jnp.where(cold_ids >= 0,
                               table[jnp.clip(cold_ids, 0)], 0.0)
        return theta_cold, {"cold_ids": cold_ids,
                            "overflow": jnp.zeros((), jnp.int32)}

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        return _dense_reduce(ctx, cold_loc, grads_flat, fwd["cold_ids"])

    def bytes_per_device(self, ctx):
        # forward ring all_gather + reduce psum_scatter, each moving
        # (P-1) blocks of |F|/P rows through every device
        return 2 * ctx.block_size * (ctx.num_shards - 1) * 4


class PsumScatterStrategy(DistributionStrategy):
    """Hybrid: sparse shuffle forward, dense psum_scatter reduce.

    Keeps the forward wire cost |F|-independent while collapsing the reduce
    into one fused collective — attractive when the backward shuffle (not
    the lookup) is the bottleneck and a transient (|F|,) accumulation
    buffer per device is affordable.
    """

    name = "psum_scatter"

    def distribute(self, ctx, cold_loc, cold_ids):
        return _sparse_distribute(ctx, cold_loc, cold_ids)

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        return _dense_reduce(ctx, cold_loc, grads_flat, fwd["cold_ids"])

    def bytes_per_device(self, ctx):
        return (2 * ctx.num_shards * ctx.capacity * 4
                + ctx.block_size * (ctx.num_shards - 1) * 4)


_REGISTRY: Dict[str, DistributionStrategy] = {}


def register_strategy(name: str, strategy: DistributionStrategy = None):
    """Register a strategy instance, or use as a class decorator:

        @register_strategy("mine")
        class Mine(DistributionStrategy): ...
    """
    if strategy is not None:
        # shallow-copy so aliasing an existing instance doesn't rename it
        inst = copy.copy(strategy)
        inst.name = name
        _REGISTRY[name] = inst
        return inst

    def _decorate(cls):
        inst = cls() if isinstance(cls, type) else cls
        inst.name = name
        _REGISTRY[name] = inst
        return cls

    return _decorate


def get_strategy(name: str) -> DistributionStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown distribution strategy {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def list_strategies() -> List[str]:
    return sorted(_REGISTRY)


register_strategy("a2a", AllToAllStrategy())
register_strategy("allgather", AllGatherStrategy())
register_strategy("psum_scatter", PsumScatterStrategy())
