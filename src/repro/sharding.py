"""Logical-axis -> mesh-axis sharding rules (MaxText-style, divisibility-aware).

Model code annotates every parameter with *logical* axis names; at jit time we
translate them to PartitionSpecs for the concrete mesh, dropping any mapping
that does not divide the dimension (e.g. 8 kv heads cannot shard over a
16-way `model` axis -> replicated).

The DPMR dense face is expressed here: the `embed`/`mlp_embed` logical axes
map to the FSDP (`data`) axis — parameters are sharded across the same devices
that hold the data, exactly the paper's "parameters distributed like samples".
"""
from __future__ import annotations

from collections.abc import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisNames = tuple[str | None, ...]

# logical axis -> preference-ordered mesh axes
DEFAULT_RULES = {
    "batch": ("pod", "data"),       # data parallel
    "seq": (),                      # replicated by default (SP handled explicitly)
    "embed": ("data",),             # FSDP / dense-DPMR shard axis
    "mlp_embed": ("data",),
    "vocab": ("model",),            # sparse-face owner axis
    "heads": ("model",),            # tensor parallel
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "experts": ("model",),          # expert parallel
    "ssm_heads": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "layers": (),                   # scan dim, never sharded
    "stack": (),
    "feature_shard": ("model",),    # DPMR sparse face: feature-owner axis
    "kv_seq": ("model",),           # cache slots when kv_heads can't shard
}


def mesh_axis_size(mesh: Mesh, names: str | Sequence[str] | None) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= int(mesh.shape[n])
    return size


def logical_to_spec(
    logical: AxisNames,
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict | None = None,
) -> P:
    """Translate logical axis names to a PartitionSpec for `mesh`.

    Each dim maps to the first rule-axis (or tuple prefix of rule-axes) that
    (a) exists in the mesh, (b) divides the dim size and (c) is not already
    used by another dim of this array.
    """
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for dim, name in zip(shape, logical, strict=True):
        if name is None:
            out.append(None)
            continue
        candidates = rules.get(name, ())
        picked: list = []
        for ax in candidates:
            if ax not in mesh.axis_names or ax in used:
                continue
            trial = picked + [ax]
            if dim % mesh_axis_size(mesh, trial) == 0:
                picked = trial
        if picked:
            used.update(picked)
            out.append(tuple(picked) if len(picked) > 1 else picked[0])
        else:
            out.append(None)
    return P(*out)


class Annotated:
    """A (shape, dtype, logical_axes) parameter declaration."""

    __slots__ = ("shape", "dtype", "logical")

    def __init__(self, shape, dtype, logical):
        assert len(shape) == len(logical), (shape, logical)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype
        self.logical = tuple(logical)

    def spec(self, mesh: Mesh, rules=None) -> P:
        return logical_to_spec(self.logical, self.shape, mesh, rules)

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def __repr__(self):
        return f"Annotated({self.shape}, {self.dtype}, {self.logical})"


def tree_specs(defs, mesh: Mesh, rules=None):
    """Pytree of Annotated -> pytree of PartitionSpec."""
    return jax.tree.map(
        lambda a: a.spec(mesh, rules), defs, is_leaf=lambda x: isinstance(x, Annotated)
    )


def tree_shardings(defs, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, a.spec(mesh, rules)),
        defs,
        is_leaf=lambda x: isinstance(x, Annotated),
    )


def tree_sds(defs):
    return jax.tree.map(
        lambda a: a.sds(), defs, is_leaf=lambda x: isinstance(x, Annotated)
    )


def init_from_defs(defs, key, scale_fn=None):
    """Materialize parameters from Annotated defs with fan-in scaled normals.

    `scale_fn(path, ann) -> float stddev` overrides the default 1/sqrt(fan_in).
    """
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, Annotated)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, ann in zip(keys, leaves, strict=True):
        if scale_fn is not None:
            std = scale_fn(ann)
        else:
            fan_in = ann.shape[-2] if len(ann.shape) >= 2 else max(ann.shape[-1], 1)
            std = 1.0 / np.sqrt(max(fan_in, 1))
        if np.issubdtype(np.dtype(ann.dtype), np.floating):
            if len(ann.shape) == 1 or "norm" in str(ann.logical):
                val = jnp.ones(ann.shape, ann.dtype)
            else:
                val = (jax.random.normal(k, ann.shape, jnp.float32) * std).astype(
                    ann.dtype
                )
        else:
            val = jnp.zeros(ann.shape, ann.dtype)
        out.append(val)
    return jax.tree.unflatten(treedef, out)


def batch_spec(mesh: Mesh, *trailing) -> P:
    """PartitionSpec with the batch dim over all DP axes present in mesh."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    lead = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(lead, *trailing)
