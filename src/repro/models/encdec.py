"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, D) supplied by input_specs(). No
RoPE (rope_theta=0); sinusoidal absolute positions are added to both sides.
Decode shapes lower the decoder serve step: self-attention KV cache of
seq_len slots + precomputed cross-attention K/V over the encoded frames
(ENC_FRAMES positions, whisper's native 1500).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import common, layers
from repro.sharding import Annotated

ENC_FRAMES = 1500


def encdec_defs(cfg: ModelConfig) -> dict:
    enc_layer = {
        "attn": layers.attn_defs(cfg),
        "mlp": layers.mlp_defs(cfg),
        "ln1": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
        "ln2": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
    }
    dec_layer = {
        "attn": layers.attn_defs(cfg),
        "xattn": layers.attn_defs(cfg),
        "mlp": layers.mlp_defs(cfg),
        "ln1": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
        "lnx": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
        "ln2": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
    }
    return {
        "encoder": common.stack_defs(enc_layer, cfg.encoder_layers),
        "ln_enc": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
        "layers": common.stack_defs(dec_layer, cfg.num_layers),
        **common.embed_defs(cfg),
    }


def encode(params, frames, cfg: ModelConfig, parallel=None):
    """frames: (B, S_enc, D) stub embeddings -> (B, S_enc, D)."""
    parallel = parallel or ParallelConfig()
    b, s, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + layers.sinusoidal_positions(s, d, x.dtype)[None]

    def body(x, lp):
        h = layers.layer_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + layers.attention_block(lp["attn"], h, cfg, None, causal=False,
                                       attn_mode=parallel.attn_mode)
        h = layers.layer_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(lp["mlp"], h, cfg)
        return x, None

    if parallel.remat != "none":
        body = jax.checkpoint(body)
    x, _ = common.scan_or_unroll(body, x, params["encoder"],
                                 unroll=not parallel.scan_layers)
    return layers.layer_norm(x, params["ln_enc"], cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg: ModelConfig, parallel=None):
    """Teacher-forced decoder -> logits (B, S_dec, V)."""
    parallel = parallel or ParallelConfig()
    b, s = tokens.shape
    x = common.embed_tokens(params, tokens, cfg)
    x = x + layers.sinusoidal_positions(s, cfg.d_model, x.dtype)[None]

    def body(x, lp):
        h = layers.layer_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + layers.attention_block(lp["attn"], h, cfg, None, causal=True,
                                       attn_mode=parallel.attn_mode)
        h = layers.layer_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + layers.attention_block(lp["xattn"], h, cfg, None,
                                       causal=False, kv_x=enc_out,
                                       attn_mode=parallel.attn_mode)
        h = layers.layer_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(lp["mlp"], h, cfg)
        return x, None

    if parallel.remat != "none":
        body = jax.checkpoint(body)
    x, _ = common.scan_or_unroll(body, x, params["layers"],
                                 unroll=not parallel.scan_layers)
    x = layers.layer_norm(x, params["ln_f"], cfg.norm_eps)
    return common.lm_head(params, x, cfg)


def forward(params, batch, cfg: ModelConfig, parallel=None):
    """batch: {frames (B,S,D), tokens (B,S)} -> (logits, aux)."""
    enc_out = encode(params, batch["frames"], cfg, parallel)
    logits = decode_train(params, batch["tokens"], enc_out, cfg, parallel)
    return logits, jnp.float32(0.0)


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    logical = ("layers", "batch", None, "kv_heads", None) if kh % 16 == 0 \
        else ("layers", "batch", "kv_seq", None, None)
    self_kv = Annotated((cfg.num_layers, batch, max_len, kh, hd), cfg.dtype,
                        logical)
    cross_kv = Annotated((cfg.num_layers, batch, ENC_FRAMES, kh, hd),
                         cfg.dtype,
                         ("layers", "batch", None, "kv_heads", None))
    return {
        "k": self_kv,
        "v": Annotated(self_kv.shape, cfg.dtype, self_kv.logical),
        "xk": cross_kv,
        "xv": Annotated(cross_kv.shape, cfg.dtype, cross_kv.logical),
        "length": Annotated((batch,), "int32", ("batch",)),
    }


def precompute_cross_kv(params, enc_out, cfg: ModelConfig):
    """Build the cross-attention K/V once per request (prefill side)."""

    def per_layer(lp, _):
        k, v = layers.project_kv(lp["xattn"], enc_out, cfg)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(
        lambda c, lp: per_layer(lp, c), None, params["layers"]
    )
    return ks, vs


def prefill(params, batch, cfg: ModelConfig, parallel=None):
    """Encode + teacher-forced decoder prefill.

    batch: {frames (B,S_enc,D), tokens (B,S_dec)}.
    Returns (last-token logits, cache with self-KV over S_dec slots and
    cross-KV over the encoded frames).
    """
    parallel = parallel or ParallelConfig()
    enc_out = encode(params, batch["frames"], cfg, parallel)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = common.embed_tokens(params, tokens, cfg)
    x = x + layers.sinusoidal_positions(s, cfg.d_model, x.dtype)[None]

    def body(x, lp):
        h = layers.layer_norm(x, lp["ln1"], cfg.norm_eps)
        q = layers.project_q(lp["attn"], h, cfg)
        k, v = layers.project_kv(lp["attn"], h, cfg)
        att = layers.blocked_causal_attention(q, k, v)
        x = x + layers.project_out(lp["attn"], att, x.dtype)
        h = layers.layer_norm(x, lp["lnx"], cfg.norm_eps)
        xk, xv = layers.project_kv(lp["xattn"], enc_out, cfg)
        qx = layers.project_q(lp["xattn"], h, cfg)
        attx = layers._bidirectional_blocked(qx, xk, xv)
        x = x + layers.project_out(lp["xattn"], attx, x.dtype)
        h = layers.layer_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(lp["mlp"], h, cfg)
        return x, (k, v, xk, xv)

    if parallel.remat != "none":
        body = jax.checkpoint(body)
    x, (k_all, v_all, xk_all, xv_all) = common.scan_or_unroll(
        body, x, params["layers"], unroll=not parallel.scan_layers)
    x = layers.layer_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x[:, -1:], cfg)
    pad = ((0, 0), (0, 0), (0, 32), (0, 0), (0, 0))   # decode headroom
    cache = {"k": jnp.pad(k_all, pad), "v": jnp.pad(v_all, pad),
             "xk": xk_all, "xv": xv_all,
             "length": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def decode_step(params, cache, tokens, cfg: ModelConfig,
                unroll: bool = False):
    """One decoder token. tokens: (B, 1)."""
    b = tokens.shape[0]
    pos = cache["length"]
    x = common.embed_tokens(params, tokens, cfg)
    # gather per-batch sinusoidal position embedding
    postab = layers.sinusoidal_positions(cache["k"].shape[2], cfg.d_model,
                                         x.dtype)
    x = x + postab[jnp.minimum(pos, postab.shape[0] - 1)][:, None, :]

    def body(x, per_layer):
        lp, k_l, v_l, xk_l, xv_l = per_layer
        h = layers.layer_norm(x, lp["ln1"], cfg.norm_eps)
        q = layers.project_q(lp["attn"], h, cfg)
        k_new, v_new = layers.project_kv(lp["attn"], h, cfg)
        slot = jnp.minimum(pos, k_l.shape[1] - 1)
        oh = jax.nn.one_hot(slot, k_l.shape[1],
                            dtype=k_l.dtype)[:, :, None, None]
        k_l = k_l * (1 - oh) + k_new[:, 0][:, None] * oh
        v_l = v_l * (1 - oh) + v_new[:, 0][:, None] * oh
        att = layers.decode_attention(q, k_l, v_l, pos + 1)
        x = x + layers.project_out(lp["attn"], att, x.dtype)

        h = layers.layer_norm(x, lp["lnx"], cfg.norm_eps)
        qx = layers.project_q(lp["xattn"], h, cfg)
        attx = layers.decode_attention(qx, xk_l, xv_l,
                                       jnp.full((b,), xk_l.shape[1]))
        x = x + layers.project_out(lp["xattn"], attx, x.dtype)

        h = layers.layer_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(lp["mlp"], h, cfg)
        return x, (k_l, v_l)

    x, (k_all, v_all) = common.scan_or_unroll(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=unroll
    )
    x = layers.layer_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x, cfg)
    new_cache = dict(cache, k=k_all, v=v_all, length=cache["length"] + 1)
    return logits, new_cache
