"""Shared model layers: norms, RoPE, blocked attention (GQA/SWA), MLPs.

All layers are pure functions over parameter pytrees. Parameter *definitions*
(shape/dtype/logical axes) are built by the ``*_defs`` functions; the logical
axes drive sharding (see repro.sharding). Attention is implemented blockwise
(online softmax) so 32k-context prefill never materializes an S x S score
matrix; a triangular python-unrolled schedule avoids causal-mask FLOP waste
for moderate block counts (the Pallas kernel in repro.kernels.flash_attention
is the TPU-optimized equivalent and is validated against this code).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ModelConfig
from repro.sharding import Annotated

# Dry-run cost-probe mode: XLA's cost_analysis counts while-loop bodies once,
# so probes (benchmarks/roofline.py via launch/dryrun.py --probe) set this to
# eliminate inner scans: python-unrolled q loops + single kv blocks. Never
# enabled for execution — compile-only probes (ShapeDtypeStructs).
PROBE_UNROLL = False

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------


def adtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def head_norm(x, scale, eps: float):
    """qk-norm: RMS-normalize the head_dim axis (chameleon)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> (sin, cos) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (B, S, H, D); sin/cos: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int, dtype=jnp.float32):
    pos = np.arange(length)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d_model)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype=dtype)


# ---------------------------------------------------------------------------
# attention parameter defs
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pt = cfg.param_dtype
    defs = {
        "wq": Annotated((d, h, hd), pt, ("embed", "heads", None)),
        "wk": Annotated((d, kh, hd), pt, ("embed", "kv_heads", None)),
        "wv": Annotated((d, kh, hd), pt, ("embed", "kv_heads", None)),
        "wo": Annotated((h, hd, d), pt, ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = Annotated((hd,), pt, (None,))
        defs["k_norm"] = Annotated((hd,), pt, (None,))
    return defs


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pt = cfg.param_dtype
    if cfg.mlp_type == "swiglu":
        return {
            "wi_gate": Annotated((d, f), pt, ("mlp_embed", "ff")),
            "wi_up": Annotated((d, f), pt, ("mlp_embed", "ff")),
            "wo": Annotated((f, d), pt, ("ff", "mlp_embed")),
        }
    return {
        "wi": Annotated((d, f), pt, ("mlp_embed", "ff")),
        "wo": Annotated((f, d), pt, ("ff", "mlp_embed")),
    }


# ---------------------------------------------------------------------------
# blocked attention core
# ---------------------------------------------------------------------------


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)).reshape(
        b, s, kh * n_rep, hd
    )


def _attn_block(q, k, v, m, l, acc, mask, scale):
    """One online-softmax step. q:(B,qb,H,D) k/v:(B,kb,H,D) mask:(qb,kb)|None."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if mask is not None:
        s = jnp.where(mask[None, None, :, :], s, -1e30)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
    return m_new, l_new, acc_new


def blocked_causal_attention(
    q, k, v, *, window: int = 0, q_block: int = 1024, kv_block: int = 1024,
    unroll_limit: int = 64,
):
    """Causal (optionally sliding-window) attention, O(S*block) memory.

    q: (B, Sq, H, hd); k, v: (B, Skv, KH, hd) with H % KH == 0. Sq == Skv
    (training / prefill; use `decode_attention` for cached decode).

    Schedule: python-unrolled triangular q-blocks (no masked-FLOP waste) when
    the block count is <= unroll_limit, else a scan with per-block masking.
    Sliding window uses a left-pad + static slice so per-q-block work is
    uniform and independent of position.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scale = 1.0 / math.sqrt(hd)
    q_block = min(q_block, sq)
    n_q = sq // q_block if sq % q_block == 0 else 1
    if sq % q_block != 0:
        q_block = sq
        n_q = 1

    if window:
        return _swa_attention(q, k, v, window, q_block, kv_block, scale)
    if n_q <= unroll_limit:
        return _triangular_attention(q, k, v, q_block, kv_block, scale)
    return _masked_scan_attention(q, k, v, q_block, kv_block, scale)


def _finalize(acc, l):
    return (acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None])


def _triangular_attention(q, k, v, q_block, kv_block, scale):
    """Python-unrolled q blocks; q block i sees kv[0 : (i+1)*q_block]."""
    b, sq, h, hd = q.shape
    outs = []
    for i in range(sq // q_block):
        qs = i * q_block
        qi = q[:, qs : qs + q_block]
        extent = qs + q_block                       # static
        ki, vi = k[:, :extent], v[:, :extent]
        m = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, h, q_block), jnp.float32)
        acc = jnp.zeros((b, q_block, h, hd), jnp.float32)
        kb = extent if PROBE_UNROLL else min(kv_block, extent)
        n_kv = extent // kb
        rem = extent - n_kv * kb

        def body(carry, blk):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(ki, blk * kb, kb, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vi, blk * kb, kb, axis=1)
            # causal mask only matters for the diagonal region
            qpos = qs + jnp.arange(q_block)
            kpos = blk * kb + jnp.arange(kb)
            mask = qpos[:, None] >= kpos[None, :]
            return _attn_block(qi, ks, vs, m, l, acc, mask, scale), None

        if n_kv:
            (m, l, acc), _ = jax.lax.scan(body, (m, l, acc), jnp.arange(n_kv))
        if rem:
            ks, vs = ki[:, n_kv * kb :], vi[:, n_kv * kb :]
            qpos = qs + jnp.arange(q_block)
            kpos = n_kv * kb + jnp.arange(rem)
            mask = qpos[:, None] >= kpos[None, :]
            m, l, acc = _attn_block(qi, ks, vs, m, l, acc, mask, scale)
        outs.append(_finalize(acc, l))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _masked_scan_attention(q, k, v, q_block, kv_block, scale):
    """Scan over q blocks x kv blocks with causal masking (tolerates waste)."""
    b, sq, h, hd = q.shape
    kv_block = min(kv_block, sq)
    n_q, n_kv = sq // q_block, sq // kv_block

    def q_body(_, iq):
        qi = jax.lax.dynamic_slice_in_dim(q, iq * q_block, q_block, axis=1)
        m = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, h, q_block), jnp.float32)
        acc = jnp.zeros((b, q_block, h, hd), jnp.float32)

        def kv_body(carry, ik):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ik * kv_block, kv_block, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, ik * kv_block, kv_block, axis=1)
            qpos = iq * q_block + jnp.arange(q_block)
            kpos = ik * kv_block + jnp.arange(kv_block)
            mask = qpos[:, None] >= kpos[None, :]
            return _attn_block(qi, ks, vs, m, l, acc, mask, scale), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m, l, acc), jnp.arange(n_kv))
        return None, _finalize(acc, l)

    _, outs = jax.lax.scan(q_body, None, jnp.arange(n_q))
    # outs: (n_q, B, q_block, H, hd) -> (B, S, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)


def _swa_attention(q, k, v, window, q_block, kv_block, scale):
    """Sliding-window causal attention via left-pad + static slices.

    For q block starting at qs, the visible kv range is
    (qs - window, qs + q_block]; after left-padding k/v by `window`, that is
    the STATIC-size slice padded[qs : qs + window + q_block].
    """
    b, sq, h, hd = q.shape
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    span = window + q_block
    n_q = sq // q_block
    if PROBE_UNROLL:
        kv_block = span

    def q_body(_, iq):
        qs = iq * q_block
        qi = jax.lax.dynamic_slice_in_dim(q, qs, q_block, axis=1)
        ki = jax.lax.dynamic_slice_in_dim(kp, qs, span, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, qs, span, axis=1)
        m = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, h, q_block), jnp.float32)
        acc = jnp.zeros((b, q_block, h, hd), jnp.float32)
        kb = min(kv_block, span)
        n_kv = span // kb
        rem = span - n_kv * kb

        def kv_body(carry, ik):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(ki, ik * kb, kb, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vi, ik * kb, kb, axis=1)
            # global positions: q = qs + i ; k = qs - window + ik*kb + j
            qpos = jnp.arange(q_block)[:, None] + window          # relative
            kpos = ik * kb + jnp.arange(kb)[None, :]
            valid = (kpos <= qpos) & (kpos > qpos - window)
            # also mask the left padding (global k index >= 0)
            valid &= (qs - window + kpos) >= 0
            return _attn_block(qi, ks, vs, m, l, acc, valid, scale), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m, l, acc), jnp.arange(n_kv))
        if rem:
            ks, vs = ki[:, n_kv * kb :], vi[:, n_kv * kb :]
            qpos = jnp.arange(q_block)[:, None] + window
            kpos = n_kv * kb + jnp.arange(rem)[None, :]
            valid = (kpos <= qpos) & (kpos > qpos - window)
            valid &= (qs - window + kpos) >= 0
            m, l, acc = _attn_block(qi, ks, vs, m, l, acc, valid, scale)
        return None, _finalize(acc, l)

    if PROBE_UNROLL:
        outs = [q_body(None, jnp.int32(i))[1] for i in range(n_q)]
        outs = jnp.stack(outs, 0)
    else:
        _, outs = jax.lax.scan(q_body, None, jnp.arange(n_q))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)


def context_parallel_attention(q, k, v, *, causal: bool = True,
                               window: int = 0, axis: str = "model",
                               kv_block: int = 1024):
    """Context-parallel attention: q (and the output) stay SEQUENCE-sharded
    over `axis`; only k/v are gathered (GQA: KH heads ~ D/16 of the residual
    bytes). This replaces the Megatron-SP all-gather(x)+reduce-scatter(out)
    pair around attention — the dominant collective in the train-cell
    baselines — and also un-replicates attention for archs whose head count
    does not divide the model axis (whisper: 12 heads vs 16).

    Formulation: q is reshaped to (B, C, S/C, H, hd) with the CHUNK dim C
    equal to (and sharded over) the model-axis size; k/v are constrained
    replicated (GSPMD inserts exactly one kv all-gather). The kv dimension
    is processed with an online-softmax scan, so no S x S buffer exists and
    no sharded dim is ever dynamically sliced (plain pjit — no shard_map;
    masking handles causality, ~2x masked-FLOP waste on attention).

    Falls back to the blocked implementations when there is no model axis
    or S does not divide it.
    """
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()
    b, s, h, hd = q.shape
    if (mesh is None or mesh.empty or axis not in mesh.axis_names
            or mesh.shape[axis] == 1 or s % mesh.shape[axis] != 0):
        if not causal:
            return _bidirectional_blocked(q, k, v)
        return blocked_causal_attention(q, k, v, window=window)

    c = int(mesh.shape[axis])
    s_loc = s // c
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scale = 1.0 / math.sqrt(hd)
    skv = k.shape[1]
    kb = skv if (PROBE_UNROLL or skv % kv_block) else kv_block
    n_kv = skv // kb

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    lead = dp if len(dp) > 1 else (dp[0] if dp else None)
    if lead is not None:
        sz = mesh.shape[dp[0]] if len(dp) == 1 else \
            int(np.prod([mesh.shape[a] for a in dp]))
        if b % sz != 0:
            lead = None
    qc = q.reshape(b, c, s_loc, h, hd)
    qc = jax.lax.with_sharding_constraint(qc, P(lead, axis, None, None, None))
    k = jax.lax.with_sharding_constraint(k, P(lead, None, None, None))
    v = jax.lax.with_sharding_constraint(v, P(lead, None, None, None))

    # global q positions per (chunk, local) element
    qpos = (jnp.arange(c)[:, None] * s_loc
            + jnp.arange(s_loc)[None, :])                    # (C, S_loc)

    def kv_body(carry, ik):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ik * kb, kb, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ik * kb, kb, axis=1)
        sblk = jnp.einsum("bcqhd,bkhd->bchqk", qc.astype(jnp.float32),
                          ks.astype(jnp.float32)) * scale
        kpos = ik * kb + jnp.arange(kb)                      # (kb,)
        if causal and window:
            mask = (qpos[:, :, None] >= kpos[None, None, :]) & \
                (kpos[None, None, :] > qpos[:, :, None] - window)
        elif causal:
            mask = qpos[:, :, None] >= kpos[None, None, :]
        else:
            mask = jnp.ones((c, s_loc, kb), bool)
        sblk = jnp.where(mask[None, :, None, :, :], sblk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sblk, axis=-1))
        p = jnp.exp(sblk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bchqk,bkhd->bchqd", p.astype(v.dtype), vs,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, c, h, s_loc), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, c, h, s_loc), jnp.float32)
    a0 = jnp.zeros((b, c, h, s_loc, hd), jnp.float32)
    if PROBE_UNROLL:
        carry = (m0, l0, a0)
        for i in range(n_kv):
            carry, _ = kv_body(carry, jnp.int32(i))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      jnp.arange(n_kv))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 2, 3)                # (B, C, S_loc, H, hd)
    out = jax.lax.with_sharding_constraint(
        out, P(lead, axis, None, None, None))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool = True):
    """Reference O(S^2)-memory attention (small shapes / oracles only)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(hd)
    if causal:
        skv = k.shape[1]
        mask = jnp.arange(sq)[:, None] + (skv - sq) >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def _constrain_scores(scores):
    """Keep decode scores sharded over the cache-slot dim (last axis): the
    softmax over a sharded axis costs two tiny all-reduces, vs GSPMD's
    default of all-gathering the slot-sharded KV cache per layer (~1 GiB per
    layer on yi-6b decode_32k)."""
    try:
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        if (mesh is None or mesh.empty or "model" not in mesh.axis_names
                or scores.shape[-1] % mesh.shape["model"] != 0):
            return scores
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        lead = dp if len(dp) > 1 else (dp[0] if dp else None)
        if lead is not None:
            sz = int(np.prod([mesh.shape[a] for a in
                              (dp if isinstance(lead, tuple) else (lead,))]))
            if scores.shape[0] % sz != 0:
                lead = None
        return jax.lax.with_sharding_constraint(
            scores, P(lead, None, None, "model"))
    except Exception:
        return scores


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-step decode: q (B,1,H,hd) vs cache (B,S,KH,hd), masked to
    cache_len (int32 scalar or (B,) vector). Window: ring-buffer semantics —
    every cache slot is valid (caller maintains the ring)."""
    b, s, kh, hd = k_cache.shape
    h = q.shape[2]
    k = _repeat_kv(k_cache, h // kh)
    v = _repeat_kv(v_cache, h // kh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = _constrain_scores(scores)
    if window:
        valid = jnp.arange(s)[None, :] < jnp.reshape(
            jnp.minimum(cache_len, s), (-1, 1)
        )
    else:
        valid = jnp.arange(s)[None, :] < jnp.reshape(cache_len, (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention module (projections + core + output)
# ---------------------------------------------------------------------------


def project_q(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        q = head_norm(q, p["q_norm"], cfg.norm_eps)
    return q


def project_kv(p, x, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        k = head_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


def project_out(p, attn_out, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"].astype(x_dtype),
                      preferred_element_type=jnp.float32).astype(x_dtype)


def attention_block(p, x, cfg: ModelConfig, positions, *, causal=True,
                    kv_x=None, use_blocked=True, attn_mode: str = "auto"):
    """Full attention block for train/prefill. kv_x: cross-attention source.

    attn_mode="cp": context-parallel — q/output sequence-sharded over the
    model axis, kv-only gather (see context_parallel_attention)."""
    src = x if kv_x is None else kv_x
    q = project_q(p, x, cfg)
    k, v = project_kv(p, src, cfg)
    if cfg.rope_theta:
        sin, cos = rope_tables(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        if kv_x is None:
            k = apply_rope(k, sin, cos)
    if attn_mode == "cp":
        out = context_parallel_attention(
            q, k, v, causal=(causal and kv_x is None),
            window=cfg.sliding_window)
    elif kv_x is not None or not causal:
        out = full_attention(q, k, v, causal=False) if not use_blocked else \
            _bidirectional_blocked(q, k, v)
    else:
        out = blocked_causal_attention(q, k, v, window=cfg.sliding_window)
    return project_out(p, out, x.dtype)


def _bidirectional_blocked(q, k, v, q_block: int = 1024, kv_block: int = 1024):
    """Non-causal blocked attention (encoder / cross-attention)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    scale = 1.0 / math.sqrt(hd)
    if sq % q_block != 0:
        q_block = sq
    skv = k.shape[1]
    kb = skv if PROBE_UNROLL else min(kv_block, skv)
    if skv % kb != 0:
        kb = skv
    n_q, n_kv = sq // q_block, skv // kb

    def q_body(_, iq):
        qi = jax.lax.dynamic_slice_in_dim(q, iq * q_block, q_block, axis=1)
        m = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, h, q_block), jnp.float32)
        acc = jnp.zeros((b, q_block, h, hd), jnp.float32)

        def kv_body(carry, ik):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ik * kb, kb, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, ik * kb, kb, axis=1)
            return _attn_block(qi, ks, vs, m, l, acc, None, scale), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m, l, acc), jnp.arange(n_kv))
        return None, _finalize(acc, l)

    if PROBE_UNROLL:
        outs = jnp.stack([q_body(None, jnp.int32(i))[1] for i in range(n_q)], 0)
    else:
        _, outs = jax.lax.scan(q_body, None, jnp.arange(n_q))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_block(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(h).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)
