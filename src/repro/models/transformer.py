"""Decoder-only transformer (dense family; chameleon reuses it with qk_norm).

Layers are stacked on a leading `layers` dim and executed with lax.scan +
optional remat — compile time and HLO size are independent of depth, which is
what makes the 126-layer llama3-405b dry-run tractable.

Sharding: parameters carry logical axes (see repro.sharding); activations get
with_sharding_constraint at block boundaries. The FSDP (`data`-axis) param
sharding *is* the DPMR dense face: XLA materializes per-layer all-gather
(distributeParameters) inside the scan and reduce-scatter of grads
(the feature-keyed reduce) in the backward pass.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import common, layers
from repro.sharding import Annotated

PREFILL_EXTRA = 32   # decode headroom appended to non-SWA prefill caches


def transformer_defs(cfg: ModelConfig) -> dict:
    from repro.models import moe as moe_mod

    layer = {
        "attn": layers.attn_defs(cfg),
        "mlp": moe_mod.moe_defs(cfg) if cfg.num_experts else layers.mlp_defs(cfg),
        "ln1": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
        "ln2": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
    }
    return {
        "layers": common.stack_defs(layer, cfg.num_layers),
        **common.embed_defs(cfg),
    }


def _ffn(p, x, cfg: ModelConfig, moe_group: int = 512):
    """Dense MLP or MoE; returns (out, aux_loss)."""
    if cfg.num_experts:
        from repro.models import moe as moe_mod

        return moe_mod.moe_block(p, x, cfg, group_size=moe_group)
    return layers.mlp_block(p, x, cfg), jnp.float32(0.0)


def _constrain(x, spec_tail):
    """Shard batch over DP axes + given tail; no-op outside a mesh context."""
    try:
        import jax.interpreters.pxla  # noqa: F401

        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        lead = dp if len(dp) > 1 else (dp[0] if dp else None)
        tail = [
            t if (t is None or t in mesh.axis_names) else None for t in spec_tail
        ]
        # drop axis if it does not divide
        for i, t in enumerate(tail):
            if t is not None and x.shape[1 + i] % mesh.shape[t] != 0:
                tail[i] = None
        if lead is not None and isinstance(lead, tuple):
            sz = 1
            for a in lead:
                sz *= mesh.shape[a]
            if x.shape[0] % sz != 0:
                lead = None
        elif lead is not None and x.shape[0] % mesh.shape[lead] != 0:
            lead = None
        return jax.lax.with_sharding_constraint(x, P(lead, *tail))
    except Exception:
        return x


def decoder_layer(p, x, cfg: ModelConfig, positions, sp: bool = True,
                  attn_mode: str = "auto", moe_group: int = 512):
    """x: (B, S, D) -> ((B, S, D), aux). Pre-norm residual block.

    sp: sequence-parallel residual — the stream (and thus remat-saved
    activations) is sharded over `model` along S between blocks; attention/
    MLP internals re-shard to head/ff parallelism as GSPMD propagates from
    the weight shardings (Megatron-SP on the cheap).
    attn_mode="cp": attention computed context-parallel (kv-only gather)."""
    tail = ("model", None) if sp else (None, None)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn = layers.attention_block(p["attn"], h, cfg, positions,
                                  attn_mode=attn_mode)
    x = x + _constrain(attn, tail)
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if attn_mode == "cp" and sp and not cfg.num_experts:
        # hybrid: attention is context-parallel (kv-only gather), but the
        # dense MLP goes Megatron-SP — gather h over S once, compute with
        # the ff dim sharded, reduce-scatter back via the residual
        # constraint. Leaving h S-sharded makes GSPMD all-gather the FULL
        # mlp weights per layer instead (36 GiB/layer on llama3-405b).
        # MoE layers skip this: routing/dispatch are per-token ops, so the
        # S-sharded stream feeds the expert a2a directly.
        h = _constrain(h, (None, None))
    ff, aux = _ffn(p["mlp"], h, cfg, moe_group)
    x = x + _constrain(ff, tail)
    return x, aux


def forward(params, tokens, cfg: ModelConfig,
            parallel: ParallelConfig | None = None):
    """Train/prefill forward -> (logits (B, S, V) f32, aux_loss scalar)."""
    parallel = parallel or ParallelConfig()
    b, s = tokens.shape
    sp = parallel.seq_shard
    tail = ("model", None) if sp else (None, None)
    x = common.embed_tokens(params, tokens, cfg)
    x = _constrain(x, tail)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    def body(carry, lp):
        x, aux = carry
        x, a = decoder_layer(lp, x, cfg, positions, sp=sp,
                             attn_mode=parallel.attn_mode,
                             moe_group=parallel.moe_group)
        return (x, aux + a), None

    if parallel.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
            if parallel.remat == "full"
            else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    carry = (x, jnp.float32(0.0))
    if parallel.scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry, params["layers"])
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            carry, _ = body(carry, lp)
        x, aux = carry

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return common.lm_head(params, x, cfg), aux


def prefill(params, tokens, cfg: ModelConfig,
            parallel: ParallelConfig | None = None):
    """Serve-side prefill: returns (last-token logits (B,1,V), cache).

    Collects per-layer K/V during the layer scan; under SWA the cache keeps
    the last `window` positions (ring-aligned because S % window == 0 for
    the assigned shapes).
    """
    parallel = parallel or ParallelConfig()
    b, s = tokens.shape
    x = common.embed_tokens(params, tokens, cfg)
    x = _constrain(x, (None, None))
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    slots = min(s, cfg.sliding_window) if cfg.sliding_window else s

    def body(x, lp):
        h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = layers.project_q(lp["attn"], h, cfg)
        k, v = layers.project_kv(lp["attn"], h, cfg)
        if cfg.rope_theta:
            sin, cos = layers.rope_tables(positions, cfg.resolved_head_dim,
                                          cfg.rope_theta)
            q = layers.apply_rope(q, sin, cos)
            k = layers.apply_rope(k, sin, cos)
        att = layers.blocked_causal_attention(q, k, v,
                                              window=cfg.sliding_window)
        x = x + layers.project_out(lp["attn"], att, x.dtype)
        h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
        ff, _ = _ffn(lp["mlp"], h, cfg)
        x = x + ff
        return x, (k[:, -slots:], v[:, -slots:])

    if parallel.remat != "none":
        body = jax.checkpoint(body)
    x, (k_all, v_all) = common.scan_or_unroll(
        body, x, params["layers"], unroll=not parallel.scan_layers)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x[:, -1:], cfg)
    if not cfg.sliding_window:
        # headroom for subsequent decode steps (SWA keeps the exact ring)
        pad = ((0, 0), (0, 0), (0, PREFILL_EXTRA), (0, 0), (0, 0))
        k_all = jnp.pad(k_all, pad)
        v_all = jnp.pad(v_all, pad)
    cache = {"k": k_all, "v": v_all,
             "length": jnp.full((b,), s, jnp.int32)}
    return logits, cache


# ---------------------------------------------------------------------------
# KV-cache serve path
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """KV cache defs (ring buffer of sliding_window slots under SWA).

    Sharding: kv_heads over the model axis when divisible (16-way production
    meshes); otherwise the SLOT dim shards over model (GQA head counts of
    1/4/8 would replicate a 1 TiB llama-405b decode_32k cache)."""
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    head_dim_ok = kh % 16 == 0
    logical = ("layers", "batch", None, "kv_heads", None) if head_dim_ok \
        else ("layers", "batch", "kv_seq", None, None)
    kv = Annotated((cfg.num_layers, batch, slots, kh, hd), cfg.dtype, logical)
    return {
        "k": kv,
        "v": Annotated(kv.shape, cfg.dtype, kv.logical),
        "length": Annotated((batch,), "int32", ("batch",)),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig,
                unroll: bool = False):
    """One decode step. tokens: (B, 1) int32; cache per cache_defs.

    Returns (logits (B, 1, V) f32, new_cache).
    """
    b = tokens.shape[0]
    slots = cache["k"].shape[2]
    pos = cache["length"]                                  # (B,)
    x = common.embed_tokens(params, tokens, cfg)

    def body(x, per_layer):
        lp, k_l, v_l = per_layer
        h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = layers.project_q(lp["attn"], h, cfg)
        k_new, v_new = layers.project_kv(lp["attn"], h, cfg)
        if cfg.rope_theta:
            sin, cos = layers.rope_tables(
                pos[:, None], cfg.resolved_head_dim, cfg.rope_theta
            )
            q = layers.apply_rope(q, sin, cos)
            k_new = layers.apply_rope(k_new, sin, cos)
        if cfg.sliding_window:
            slot = pos % slots            # ring buffer over window slots
        else:
            slot = jnp.minimum(pos, slots - 1)
        # one-hot masked update instead of scatter: elementwise ops keep the
        # slot-sharded cache sharding intact (a scatter on a sharded dim
        # makes GSPMD reshard the whole cache)
        oh = jax.nn.one_hot(slot, slots, dtype=k_l.dtype)[:, :, None, None]
        k_l = k_l * (1 - oh) + k_new[:, 0][:, None] * oh
        v_l = v_l * (1 - oh) + v_new[:, 0][:, None] * oh
        att = layers.decode_attention(
            q, k_l, v_l, pos + 1, window=cfg.sliding_window
        )
        x = x + layers.project_out(lp["attn"], att, x.dtype)
        h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
        ff, _ = _ffn(lp["mlp"], h, cfg)
        x = x + ff
        return x, (k_l, v_l)

    x, (k_all, v_all) = common.scan_or_unroll(
        body, x, (params["layers"], cache["k"], cache["v"]), unroll=unroll
    )
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x, cfg)
    new_cache = {"k": k_all, "v": v_all, "length": cache["length"] + 1}
    return logits, new_cache
