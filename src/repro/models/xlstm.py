"""xLSTM blocks: mLSTM (matrix memory, parallelizable via chunked linear
attention) and sLSTM (scalar memory, true recurrence via lax.scan).

Block layout follows xlstm-125m: `slstm_every`-th blocks are sLSTM, the rest
mLSTM. d_ff=0 in the assignment: capacity lives in the block up/down
projections (factor 2 for mLSTM, 4/3 GLU for sLSTM), per the xLSTM paper.

Layers are heterogeneous, so the stack is a python tuple (no layer scan);
at 12 layers the HLO stays small. mLSTM exponential input gates are clamped
and the normalizer (normalize=True) keeps magnitudes bounded — the paper's
m-stabilizer is folded into the normalizer for the chunked form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import common, layers, ssm_common
from repro.sharding import Annotated

CONV_K = 4
EXP_CLAMP = 10.0


def _mdims(cfg: ModelConfig):
    di = 2 * cfg.d_model
    h = cfg.num_heads
    dh = di // h
    return di, h, dh


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, dh = _mdims(cfg)
    pt = cfg.param_dtype
    return {
        "norm": Annotated((d,), pt, (None,)),
        "wu": Annotated((d, di), pt, ("embed", "ssm_inner")),
        "wz": Annotated((d, di), pt, ("embed", "ssm_inner")),
        "conv": Annotated((CONV_K, di), pt, (None, "ssm_inner")),
        "wq": Annotated((di, di), pt, ("ssm_inner", None)),
        "wk": Annotated((di, di), pt, ("ssm_inner", None)),
        "wv": Annotated((di, di), pt, ("ssm_inner", None)),
        "wi": Annotated((di, h), pt, ("ssm_inner", None)),
        "wf": Annotated((di, h), pt, ("ssm_inner", None)),
        "f_bias": Annotated((h,), pt, (None,)),
        "out_norm": Annotated((di,), pt, (None,)),
        "wo": Annotated((di, d), pt, ("ssm_inner", "embed")),
    }


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    pt = cfg.param_dtype
    fup = (4 * d) // 3
    return {
        "norm": Annotated((d,), pt, (None,)),
        "w_gates": Annotated((d, 4, h, dh), pt, ("embed", None, "heads", None)),
        "r_gates": Annotated((h, dh, 4, dh), pt, ("heads", None, None, None)),
        "b_gates": Annotated((4, h, dh), pt, (None, "heads", None)),
        "out_norm": Annotated((d,), pt, (None,)),
        "w_up1": Annotated((d, fup), pt, ("embed", "ff")),
        "w_up2": Annotated((d, fup), pt, ("embed", "ff")),
        "w_down": Annotated((fup, d), pt, ("ff", "embed")),
    }


def xlstm_defs(cfg: ModelConfig) -> dict:
    blocks = []
    for i in range(cfg.num_layers):
        if cfg.slstm_every and (i % cfg.slstm_every == cfg.slstm_every - 1):
            blocks.append({"kind_slstm": slstm_defs(cfg)})
        else:
            blocks.append({"kind_mlstm": mlstm_defs(cfg)})
    return {"blocks": tuple(blocks), **common.embed_defs(cfg)}


def _is_slstm(block_params) -> bool:
    return "kind_slstm" in block_params


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _conv1d(x, kernel):
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * \
            kernel[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _mlstm_qkvif(p, u, cfg: ModelConfig):
    di, h, dh = _mdims(cfg)
    b, s, _ = u.shape
    cu = jax.nn.silu(_conv1d(u, p["conv"]).astype(jnp.float32)).astype(u.dtype)
    q = jnp.einsum("bse,ef->bsf", cu, p["wq"].astype(u.dtype),
                   preferred_element_type=jnp.float32).astype(u.dtype)
    k = jnp.einsum("bse,ef->bsf", cu, p["wk"].astype(u.dtype),
                   preferred_element_type=jnp.float32).astype(u.dtype)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"].astype(u.dtype),
                   preferred_element_type=jnp.float32).astype(u.dtype)
    i_pre = jnp.einsum("bse,eh->bsh", cu, p["wi"].astype(u.dtype),
                       preferred_element_type=jnp.float32)
    f_pre = jnp.einsum("bse,eh->bsh", cu, p["wf"].astype(u.dtype),
                       preferred_element_type=jnp.float32) + \
        p["f_bias"].astype(jnp.float32)
    shp = (b, s, h, dh)
    igate = jnp.exp(jnp.minimum(i_pre, EXP_CLAMP))          # clamped exp gate
    return (q.reshape(shp), k.reshape(shp), v.reshape(shp), igate,
            jax.nn.log_sigmoid(f_pre))


def mlstm_block(p, x, cfg: ModelConfig, return_state: bool = False):
    di, h, dh = _mdims(cfg)
    b, s, d = x.shape
    hn = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", hn, p["wu"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    z = jnp.einsum("bsd,de->bse", hn, p["wz"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q, k, v, igate, log_f = _mlstm_qkvif(p, u, cfg)
    k = k * (igate[..., None] / jnp.sqrt(dh)).astype(k.dtype)
    res = ssm_common.chunked_linear_attention(q, k, v, log_f,
                                              chunk=min(128, s),
                                              normalize=True,
                                              return_state=return_state,
                                              unroll=layers.PROBE_UNROLL)
    y, state = res if return_state else (res, None)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = layers.rms_norm(y, p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        tail = u[:, -(CONV_K - 1):]
        if s < CONV_K - 1:
            tail = jnp.pad(u, ((0, 0), (CONV_K - 1 - s, 0), (0, 0)))
        return x + out, (tail, state[0], state[1])
    return x + out


def mlstm_decode_step(p, x, cfg: ModelConfig, conv_buf, S, n):
    """x: (B,1,D); conv_buf: (B,K-1,di); S: (B,H,dh,dh); n: (B,H,dh)."""
    di, h, dh = _mdims(cfg)
    b = x.shape[0]
    hn = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", hn, p["wu"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    z = jnp.einsum("bsd,de->bse", hn, p["wz"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    seqbuf = jnp.concatenate([conv_buf, u], axis=1)
    cu = jax.nn.silu(jnp.einsum("bkc,kc->bc", seqbuf.astype(jnp.float32),
                                p["conv"].astype(jnp.float32)))
    cu = cu[:, None, :].astype(x.dtype)
    new_buf = seqbuf[:, 1:]

    q = jnp.einsum("bse,ef->bsf", cu, p["wq"].astype(x.dtype))[:, 0]
    k = jnp.einsum("bse,ef->bsf", cu, p["wk"].astype(x.dtype))[:, 0]
    v = jnp.einsum("bse,ef->bsf", u, p["wv"].astype(x.dtype))[:, 0]
    i_pre = jnp.einsum("bse,eh->bsh", cu, p["wi"].astype(x.dtype))[:, 0]
    f_pre = jnp.einsum("bse,eh->bsh", cu, p["wf"].astype(x.dtype))[:, 0] + \
        p["f_bias"].astype(jnp.float32)
    igate = jnp.exp(jnp.minimum(i_pre.astype(jnp.float32), EXP_CLAMP))
    shp = (b, h, dh)
    k = k.reshape(shp) * (igate[..., None] / jnp.sqrt(dh)).astype(k.dtype)
    y, S, n = ssm_common.linear_attention_step(
        S, q.reshape(shp), k, v.reshape(shp),
        jax.nn.log_sigmoid(f_pre.astype(jnp.float32)),
        norm_state=n, normalize=True)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = layers.rms_norm(y, p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return x + out, new_buf, S, n


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _slstm_cell(gates, state):
    """gates: (B,H,4,dh) pre-activations [z,i,f,o]; state: (c,n,m,h)."""
    c, n, m, hprev = state
    zp, ip, fp, op = (gates[:, :, j] for j in range(4))
    z = jnp.tanh(zp)
    o = jax.nn.sigmoid(op)
    m_new = jnp.maximum(fp + m, ip)
    i = jnp.exp(ip - m_new)
    f = jnp.exp(fp + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return (c_new, n_new, m_new, h_new)


def _slstm_gates(p, x_t, h_prev):
    """x_t: (B,D); h_prev: (B,H,dh) -> (B,H,4,dh) pre-activations."""
    wx = jnp.einsum("bd,dghe->bhge", x_t.astype(jnp.float32),
                    p["w_gates"].astype(jnp.float32))
    wr = jnp.einsum("bhe,hegf->bhgf", h_prev,
                    p["r_gates"].astype(jnp.float32))
    return wx + wr + p["b_gates"].astype(jnp.float32).transpose(1, 0, 2)[None]


def slstm_block(p, x, cfg: ModelConfig, return_state: bool = False):
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    hn = layers.rms_norm(x, p["norm"], cfg.norm_eps)

    def step(state, x_t):
        gates = _slstm_gates(p, x_t, state[3])
        state = _slstm_cell(gates, state)
        return state, state[3]

    z0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h, dh), -jnp.inf, jnp.float32)
    fstate, hs = jax.lax.scan(step, (z0, z0, m0, z0), jnp.moveaxis(hn, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = layers.rms_norm(y, p["out_norm"], cfg.norm_eps)
    u1 = jnp.einsum("bsd,df->bsf", y, p["w_up1"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    u2 = jnp.einsum("bsd,df->bsf", y, p["w_up2"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    g = (jax.nn.gelu(u1) * u2).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", g, p["w_down"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        return x + out, fstate
    return x + out


def slstm_decode_step(p, x, cfg: ModelConfig, state):
    b = x.shape[0]
    hn = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    gates = _slstm_gates(p, hn[:, 0], state[3])
    state = _slstm_cell(gates, state)
    d = x.shape[-1]
    y = state[3].reshape(b, 1, d).astype(x.dtype)
    y = layers.rms_norm(y, p["out_norm"], cfg.norm_eps)
    u1 = jnp.einsum("bsd,df->bsf", y, p["w_up1"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    u2 = jnp.einsum("bsd,df->bsf", y, p["w_up2"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    g = (jax.nn.gelu(u1) * u2).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", g, p["w_down"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return x + out, state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def xlstm_forward(params, tokens, cfg: ModelConfig, parallel=None):
    parallel = parallel or ParallelConfig()
    x = common.embed_tokens(params, tokens, cfg)
    for bp in params["blocks"]:
        if _is_slstm(bp):
            fn = lambda x, p=bp["kind_slstm"]: slstm_block(p, x, cfg)
        else:
            fn = lambda x, p=bp["kind_mlstm"]: mlstm_block(p, x, cfg)
        x = jax.checkpoint(fn)(x) if parallel.remat != "none" else fn(x)
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return common.lm_head(params, x, cfg), jnp.float32(0.0)


def xlstm_prefill(params, tokens, cfg: ModelConfig, parallel=None):
    """Prefill -> (last-token logits, cache per xlstm_cache_defs)."""
    parallel = parallel or ParallelConfig()
    b, s = tokens.shape
    x = common.embed_tokens(params, tokens, cfg)
    new_blocks = []
    for bp in params["blocks"]:
        if _is_slstm(bp):
            x, st = slstm_block(bp["kind_slstm"], x, cfg, return_state=True)
            # replace -inf stabilizer with a large negative finite value so
            # the decode cache stays IEEE-clean
            m = jnp.maximum(st[2], -1e30)
            new_blocks.append({"slstm": {
                "c": st[0], "n": st[1], "m": m, "h": st[3]}})
        else:
            x, (conv, S, n) = mlstm_block(bp["kind_mlstm"], x, cfg,
                                          return_state=True)
            new_blocks.append({"mlstm": {
                "conv": conv.astype(jnp.dtype(cfg.dtype)), "S": S, "n": n}})
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x[:, -1:], cfg)
    cache = {"blocks": tuple(new_blocks),
             "length": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def xlstm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    di, h, dh = _mdims(cfg)
    dhs = cfg.d_model // cfg.num_heads
    blocks = []
    for i in range(cfg.num_layers):
        if cfg.slstm_every and (i % cfg.slstm_every == cfg.slstm_every - 1):
            st = Annotated((batch, cfg.num_heads, dhs), "float32",
                           ("batch", "heads", None))
            blocks.append({"slstm": {"c": st, "n": st, "m": st, "h": st}})
        else:
            blocks.append({"mlstm": {
                "conv": Annotated((batch, CONV_K - 1, di), cfg.dtype,
                                  ("batch", None, "ssm_inner")),
                "S": Annotated((batch, h, dh, dh), "float32",
                               ("batch", "heads", None, None)),
                "n": Annotated((batch, h, dh), "float32",
                               ("batch", "heads", None)),
            }})
    return {"blocks": tuple(blocks),
            "length": Annotated((batch,), "int32", ("batch",))}


def xlstm_decode_step(params, cache, tokens, cfg: ModelConfig,
                      unroll: bool = False):
    del unroll  # already a python loop over heterogeneous blocks
    x = common.embed_tokens(params, tokens, cfg)
    new_blocks = []
    for bp, bc in zip(params["blocks"], cache["blocks"], strict=True):
        if _is_slstm(bp):
            st = bc["slstm"]
            state = (st["c"], st["n"], st["m"], st["h"])
            x, state = slstm_decode_step(bp["kind_slstm"], x, cfg, state)
            new_blocks.append({"slstm": {
                "c": state[0], "n": state[1], "m": state[2], "h": state[3]}})
        else:
            st = bc["mlstm"]
            x, conv, S, n = mlstm_decode_step(
                bp["kind_mlstm"], x, cfg, st["conv"], st["S"], st["n"])
            new_blocks.append({"mlstm": {"conv": conv, "S": S, "n": n}})
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x, cfg)
    return logits, {"blocks": tuple(new_blocks),
                    "length": cache["length"] + 1}
