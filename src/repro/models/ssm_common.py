"""Chunked gated linear attention — the shared compute core of Mamba2 (SSD)
and mLSTM (xLSTM matrix memory).

Both compute, per head,
    y_t = q_t^T . ( sum_{s<=t}  (prod_{r=s+1..t} a_r)  k_s v_s^T )
i.e. a linear-attention state S in R^{Dk x Dv} with scalar per-step decay a_r
(per head). Mamba2: q=C, k=B, v=x-heads, a=exp(dt*A).  mLSTM: a=sigmoid(f)
forget gate, k scaled by input gate.

The chunked algorithm (chunk L):
  within-chunk (quadratic, MXU-friendly):  y_intra = ((q k^T) * decay_mask) v
  chunk states:  S_c = sum_s (a_{s+1..L}) k_s v_s^T, carried with lax.scan
  inter-chunk:   y_inter_t = (a_{1..t}) q_t^T S_{prev}
Memory is O(L^2 + Dk*Dv) per head per step — never O(S^2).

`linear_attention_step` is the O(1)-per-token decode form (state carried in
the serve cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_linear_attention(q, k, v, log_a, *, chunk: int = 128,
                             normalize: bool = False, eps: float = 1e-6,
                             return_state: bool = False,
                             unroll: bool = False):
    """q,k: (B,S,H,Dk); v: (B,S,H,Dv); log_a: (B,S,H) (log decay, <= 0).

    Returns y: (B,S,H,Dv) [f32]. If normalize, divides by the linear-attention
    normalizer n_t = q_t . (sum decayed k_s) (mLSTM-style, clamped).
    If return_state, returns (y, (S_final (B,H,Dk,Dv), n_final (B,H,Dk))) for
    prefill -> decode handoff.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    qc = q.reshape(b, nc, L, h, dk).astype(jnp.float32)
    kc = k.reshape(b, nc, L, h, dk).astype(jnp.float32)
    vc = v.reshape(b, nc, L, h, dv).astype(jnp.float32)
    lac = log_a.reshape(b, nc, L, h).astype(jnp.float32)

    def body(carry, xs):
        S, n = carry                     # S: (B,H,Dk,Dv); n: (B,H,Dk)
        qi, ki, vi, lai = xs             # (B,L,H,*)
        cum = jnp.cumsum(lai, axis=1)    # (B,L,H) log prod a_{1..t}
        total = cum[:, -1:, :]           # (B,1,H)

        # intra-chunk: decay(i,j) = exp(cum_i - cum_j) for j <= i
        scores = jnp.einsum("blhd,bmhd->bhlm", qi, ki)
        ci = jnp.moveaxis(cum, -1, 1)                            # (B,H,L)
        dm = ci[:, :, :, None] - ci[:, :, None, :]               # (B,H,L,M)
        causal = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.where(causal[None, None], jnp.exp(dm), 0.0)
        y_intra = jnp.einsum("bhlm,bmhd->blhd", scores * dec, vi)

        # inter-chunk: y += exp(cum_t) q_t . S_prev
        w = jnp.exp(cum)                                         # (B,L,H)
        y_inter = jnp.einsum("blhd,bhde->blhe", qi * w[..., None], S)
        y = y_intra + y_inter

        if normalize:
            # normalizer: n_t = sum_{s<=t} decay * k_s  (vector), y /= q.n
            k_dec = jnp.einsum("bhlm,bmhd->blhd", dec, ki)       # intra sums
            n_vec = k_dec + jnp.einsum("blh,bhd->blhd", w, n)
            denom = jnp.abs(jnp.einsum("blhd,blhd->blh", qi, n_vec))
            y = y / jnp.maximum(denom, eps)[..., None]

        # update state: S_new = exp(total) S + sum_s exp(total - cum_s) k v^T
        w_k = jnp.exp(total - cum)                               # (B,L,H)
        S_new = jnp.exp(total)[:, 0, :, None, None] * S + jnp.einsum(
            "blhd,blhe->bhde", ki * w_k[..., None], vi)
        if normalize:
            n_upd = jnp.exp(total)[:, 0, :, None] * n + jnp.einsum(
                "blhd->bhd", ki * w_k[..., None])
            return (S_new, n_upd), y
        return (S_new, n), y

    S0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    xs = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lac, 1, 0),
    )
    if unroll:
        # cost-probe path: python loop at the TRUE chunk size (a single
        # giant chunk would change the algorithm's flop count — chunked SSD
        # is linear in S, one chunk is quadratic)
        carry = (S0, n0)
        ys_list = []
        for i in range(nc):
            xi = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, xi)
            ys_list.append(y)
        (S_f, n_f), ys = carry, jnp.stack(ys_list, 0)
    else:
        (S_f, n_f), ys = jax.lax.scan(body, (S0, n0), xs)
    # ys: (nc, B, L, H, Dv) -> (B, S, H, Dv)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dv)
    if return_state:
        return y, (S_f, n_f)
    return y


def linear_attention_step(state, q, k, v, log_a, *, norm_state=None,
                          normalize: bool = False, eps: float = 1e-6):
    """O(1) decode step.

    state: (B,H,Dk,Dv); q,k: (B,H,Dk); v: (B,H,Dv); log_a: (B,H).
    Returns (y (B,H,Dv), new_state[, new_norm_state]).
    """
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = a * state + jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), state)
    if normalize:
        ns = a[..., 0] * norm_state + k.astype(jnp.float32)
        denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), ns))
        y = y / jnp.maximum(denom, eps)[..., None]
        return y, state, ns
    return y, state, norm_state
