"""Architecture registry: arch id -> defs/forward/prefill/decode + input specs.

Every assigned architecture is selectable by id (``--arch``). `input_specs`
returns Annotated trees (shape/dtype/logical axes) — the dry-run converts
them to ShapeDtypeStructs + NamedShardings without allocating anything.
"""
from __future__ import annotations

from collections.abc import Callable
import dataclasses


from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, mamba, transformer, xlstm
from repro.sharding import Annotated


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    cfg: ModelConfig
    defs: Callable                  # (cfg) -> params defs tree
    forward: Callable               # (params, batch, cfg, parallel) -> (logits, aux)
    prefill: Callable | None     # (params, batch, cfg, parallel) -> (logits, cache)
    decode_step: Callable | None  # (params, cache, tokens, cfg) -> (logits, cache)
    cache_defs: Callable | None  # (cfg, batch, max_len) -> cache defs
    supported_shapes: tuple[str, ...]
    skip_reason: str = ""           # why some shapes are skipped (DESIGN.md)


def _lm_forward(params, batch, cfg, parallel=None):
    return transformer.forward(params, batch["tokens"], cfg, parallel)


def _lm_prefill(params, batch, cfg, parallel=None):
    return transformer.prefill(params, batch["tokens"], cfg, parallel)


def _zamba_forward(params, batch, cfg, parallel=None):
    return mamba.zamba_forward(params, batch["tokens"], cfg, parallel)


def _zamba_prefill(params, batch, cfg, parallel=None):
    return mamba.zamba_prefill(params, batch["tokens"], cfg, parallel)


def _xlstm_forward(params, batch, cfg, parallel=None):
    return xlstm.xlstm_forward(params, batch["tokens"], cfg, parallel)


def _xlstm_prefill(params, batch, cfg, parallel=None):
    return xlstm.xlstm_prefill(params, batch["tokens"], cfg, parallel)


def _xlstm_cache_defs(cfg, batch, max_len):
    return xlstm.xlstm_cache_defs(cfg, batch)


_FULL_ATTN = ("train_4k", "prefill_32k", "decode_32k")
_ALL = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

_FAMILY = {
    "dense": dict(defs=transformer.transformer_defs, forward=_lm_forward,
                  prefill=_lm_prefill, decode_step=transformer.decode_step,
                  cache_defs=transformer.cache_defs),
    "hybrid": dict(defs=mamba.zamba_defs, forward=_zamba_forward,
                   prefill=_zamba_prefill, decode_step=mamba.zamba_decode_step,
                   cache_defs=mamba.zamba_cache_defs),
    "ssm": dict(defs=xlstm.xlstm_defs, forward=_xlstm_forward,
                prefill=_xlstm_prefill, decode_step=xlstm.xlstm_decode_step,
                cache_defs=_xlstm_cache_defs),
    "encdec": dict(defs=encdec.encdec_defs, forward=encdec.forward,
                   prefill=encdec.prefill, decode_step=encdec.decode_step,
                   cache_defs=encdec.cache_defs),
}
_FAMILY["moe"] = _FAMILY["dense"]
_FAMILY["vlm"] = _FAMILY["dense"]


def get_spec(arch_id: str) -> ArchSpec:
    cfg = get_config(arch_id)
    fam = _FAMILY[cfg.family]
    if cfg.family in ("hybrid", "ssm"):
        shapes, reason = _ALL, ""
    elif cfg.sliding_window:
        shapes, reason = _ALL, ""          # SWA: bounded cache at 500k
    elif cfg.family == "encdec":
        shapes = _FULL_ATTN
        reason = "long_500k skipped: full attention, quadratic at 512k"
    else:
        shapes = _FULL_ATTN
        reason = "long_500k skipped: pure full attention (dense KV cache)"
    return ArchSpec(arch_id=arch_id, cfg=cfg, supported_shapes=shapes,
                    skip_reason=reason, **fam)


def all_specs():
    return [get_spec(a) for a in ARCH_IDS]


# ---------------------------------------------------------------------------
# input specs per (arch x shape)
# ---------------------------------------------------------------------------


def train_batch_defs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    toks = Annotated((b, s), "int32", ("batch", None))
    batch = {"tokens": toks, "labels": Annotated((b, s), "int32",
                                                 ("batch", None))}
    if cfg.family == "encdec":
        batch["frames"] = Annotated((b, s, cfg.d_model), cfg.dtype,
                                    ("batch", None, None))
    return batch


def prefill_batch_defs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": Annotated((b, s), "int32", ("batch", None))}
    if cfg.family == "encdec":
        batch["frames"] = Annotated((b, s, cfg.d_model), cfg.dtype,
                                    ("batch", None, None))
    return batch


def decode_batch_defs(cfg: ModelConfig, shape: ShapeConfig,
                      spec: ArchSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    return {
        "tokens": Annotated((b, 1), "int32", ("batch", None)),
        "cache": spec.cache_defs(cfg, b, s),
    }


def batch_defs(spec: ArchSpec, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_batch_defs(spec.cfg, shape)
    if shape.kind == "prefill":
        return prefill_batch_defs(spec.cfg, shape)
    return decode_batch_defs(spec.cfg, shape, spec)


# ---------------------------------------------------------------------------
# reduced configs for smoke tests
# ---------------------------------------------------------------------------


def smoke_config(arch_id: str) -> ModelConfig:
    """Same-family reduced config: tiny widths, few layers/experts."""
    cfg = get_config(arch_id)
    r = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        param_dtype="float32",
        dtype="float32",
    )
    if cfg.num_experts:
        r.update(num_experts=4, experts_per_token=2)
    if cfg.sliding_window:
        r.update(sliding_window=8)
    if cfg.family == "hybrid":
        r.update(num_layers=4, attn_every=2, ssm_state=16)
    if cfg.family == "ssm":
        r.update(num_layers=2, slstm_every=2)
    if cfg.encoder_layers:
        r.update(encoder_layers=2)
    return dataclasses.replace(cfg, **r)
