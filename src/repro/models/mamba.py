"""Mamba2 (SSD) blocks + the zamba2 hybrid backbone.

zamba2: a stack of Mamba2 blocks with a *shared* transformer block (attention
+ MLP, one set of weights) applied every `attn_every` layers — the zamba
signature. The SSD core is `ssm_common.chunked_linear_attention` with
q=C, k=B, v=x_heads, per-head scalar decay exp(dt * -exp(A_log)).

Decode carries per-layer SSD state (B, H, N, P) + a conv tail ring — O(1) per
token, which is why zamba2 runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import common, layers, ssm_common
from repro.sharding import Annotated

P_HEAD = 64      # SSD head dim (mamba2 default)
CONV_K = 4       # depthwise conv kernel size


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // P_HEAD
    return d_inner, nheads, cfg.ssm_state


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, n = _dims(cfg)
    pt = cfg.param_dtype
    return {
        "norm": Annotated((d,), pt, (None,)),
        "wx": Annotated((d, di), pt, ("embed", "ssm_inner")),
        "wz": Annotated((d, di), pt, ("embed", "ssm_inner")),
        "wB": Annotated((d, n), pt, ("embed", None)),
        "wC": Annotated((d, n), pt, ("embed", None)),
        "wdt": Annotated((d, h), pt, ("embed", "ssm_heads")),
        "dt_bias": Annotated((h,), pt, (None,)),
        "A_log": Annotated((h,), pt, (None,)),
        "D_skip": Annotated((h,), pt, (None,)),
        "conv": Annotated((CONV_K, di), pt, (None, "ssm_inner")),
        "out_norm": Annotated((di,), pt, (None,)),
        "wo": Annotated((di, d), pt, ("ssm_inner", "embed")),
    }


def _conv1d(x, kernel):
    """Causal depthwise conv. x: (B,S,C); kernel: (K,C)."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * kernel[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _ssd_inputs(p, x, cfg: ModelConfig):
    di, h, n = _dims(cfg)
    xin = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )                                                     # (B,S,H) f32
    return xin, z, Bm, Cm, dt


def mamba_block(p, x, cfg: ModelConfig, return_state: bool = False):
    """Train/prefill SSD block. x: (B,S,D) -> (B,S,D).

    If return_state, also returns (conv_tail (B,K-1,di), ssd_state (B,H,N,P))
    for the prefill -> decode handoff.
    """
    di, h, n = _dims(cfg)
    b, s, _ = x.shape
    hdd = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    xin_raw, z, Bm, Cm, dt = _ssd_inputs(p, hdd, cfg)
    xin = _conv1d(xin_raw, p["conv"])
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)

    xh = xin.reshape(b, s, h, P_HEAD)
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32))[None, None, :] * dt
    # broadcast single B/C group across heads; dt scales the input (v)
    k = jnp.broadcast_to(Bm[:, :, None, :], (b, s, h, n))
    q = jnp.broadcast_to(Cm[:, :, None, :], (b, s, h, n))
    v = xh * dt[..., None]
    res = ssm_common.chunked_linear_attention(
        q, k, v, log_a, chunk=min(128, s), return_state=return_state,
        unroll=layers.PROBE_UNROLL)
    y, state = res if return_state else (res, None)
    y = y + xh.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        tail = xin_raw[:, -(CONV_K - 1):]
        if s < CONV_K - 1:
            tail = jnp.pad(xin_raw, ((0, 0), (CONV_K - 1 - s, 0), (0, 0)))
        return x + out, (tail, state[0])
    return x + out


def mamba_decode_step(p, x, cfg: ModelConfig, conv_buf, ssd_state):
    """One-token step. x: (B,1,D); conv_buf: (B,K-1,di); ssd_state: (B,H,N,P).

    Returns (x_out, conv_buf, ssd_state).
    """
    di, h, n = _dims(cfg)
    b = x.shape[0]
    hdd = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    xin, z, Bm, Cm, dt = _ssd_inputs(p, hdd, cfg)
    # conv over ring buffer [buf, xin]
    seqbuf = jnp.concatenate([conv_buf, xin], axis=1)       # (B,K,di)
    conv_out = jnp.einsum("bkc,kc->bc", seqbuf.astype(jnp.float32),
                          p["conv"].astype(jnp.float32))
    xin1 = jax.nn.silu(conv_out).astype(x.dtype)            # (B,di)
    new_buf = seqbuf[:, 1:]

    xh = xin1.reshape(b, h, P_HEAD)
    dt1 = dt[:, 0]                                          # (B,H)
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32))[None, :] * dt1
    k = jnp.broadcast_to(Bm[:, 0, None, :], (b, h, n))
    q = jnp.broadcast_to(Cm[:, 0, None, :], (b, h, n))
    v = xh * dt1[..., None]
    y, ssd_state, _ = ssm_common.linear_attention_step(ssd_state, q, k, v, log_a)
    y = y + xh.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return x + out, new_buf, ssd_state


# ---------------------------------------------------------------------------
# zamba2 hybrid backbone
# ---------------------------------------------------------------------------


def _n_inv(cfg: ModelConfig) -> int:
    every = max(cfg.attn_every, 1)
    assert cfg.num_layers % every == 0, (cfg.num_layers, every)
    return cfg.num_layers // every


def zamba_defs(cfg: ModelConfig) -> dict:
    shared = {
        "attn": layers.attn_defs(cfg),
        "mlp": layers.mlp_defs(cfg),
        "ln1": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
        "ln2": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
    }
    return {
        "layers": common.stack_defs(mamba_defs(cfg), cfg.num_layers),
        "shared": shared,                       # ONE shared attention block
        **common.embed_defs(cfg),
    }


def _group_params(params, cfg: ModelConfig, g: int):
    """Slice layer-group g (of `every` consecutive mamba layers)."""
    every = max(cfg.attn_every, 1)
    n = _n_inv(cfg)
    return jax.tree.map(
        lambda a: a.reshape((n, every) + a.shape[1:])[g], params["layers"]
    )


def _shared_block(params, x, cfg: ModelConfig, positions):
    sp = params["shared"]
    h = layers.rms_norm(x, sp["ln1"], cfg.norm_eps)
    x = x + layers.attention_block(sp["attn"], h, cfg, positions)
    h = layers.rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + layers.mlp_block(sp["mlp"], h, cfg)


def zamba_forward(params, tokens, cfg: ModelConfig, parallel=None):
    """Groups of `attn_every` mamba layers, each followed by the SHARED
    attention block (weights reused across all invocations)."""
    parallel = parallel or ParallelConfig()
    b, s = tokens.shape
    x = common.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    def group(x, gp):
        def body(x, lp):
            return mamba_block(lp, x, cfg), None

        x, _ = common.scan_or_unroll(body, x, gp,
                                     unroll=not parallel.scan_layers)
        return _shared_block(params, x, cfg, positions)

    gfn = jax.checkpoint(group) if parallel.remat != "none" else group
    for g in range(_n_inv(cfg)):
        x = gfn(x, _group_params(params, cfg, g))

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return common.lm_head(params, x, cfg), jnp.float32(0.0)


def zamba_cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    di, h, n = _dims(cfg)
    ninv = _n_inv(cfg)
    logical = (None, "batch", None, "kv_heads", None) \
        if cfg.num_kv_heads % 16 == 0 else \
        (None, "batch", "kv_seq", None, None)
    kv = Annotated((ninv, batch, max_len, cfg.num_kv_heads,
                    cfg.resolved_head_dim), cfg.dtype, logical)
    return {
        "conv": Annotated((cfg.num_layers, batch, CONV_K - 1, di), cfg.dtype,
                          ("layers", "batch", None, "ssm_inner")),
        "ssd": Annotated((cfg.num_layers, batch, h, n, P_HEAD), "float32",
                         ("layers", "batch", "ssm_heads", None, None)),
        "k": kv,
        "v": Annotated(kv.shape, cfg.dtype, kv.logical),
        "length": Annotated((batch,), "int32", ("batch",)),
    }


def zamba_prefill(params, tokens, cfg: ModelConfig, parallel=None):
    """Prefill -> (last-token logits, cache per zamba_cache_defs)."""
    parallel = parallel or ParallelConfig()
    b, s = tokens.shape
    x = common.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    sp = params["shared"]

    conv_all, ssd_all, k_all, v_all = [], [], [], []

    def group(x, gp):
        def body(x, lp):
            x, st = mamba_block(lp, x, cfg, return_state=True)
            return x, st

        x, (convs, ssds) = common.scan_or_unroll(
            body, x, gp, unroll=not parallel.scan_layers)
        # shared block, capturing its K/V for this invocation
        h = layers.rms_norm(x, sp["ln1"], cfg.norm_eps)
        q = layers.project_q(sp["attn"], h, cfg)
        k, v = layers.project_kv(sp["attn"], h, cfg)
        if cfg.rope_theta:
            sin, cos = layers.rope_tables(positions, cfg.resolved_head_dim,
                                          cfg.rope_theta)
            q = layers.apply_rope(q, sin, cos)
            k = layers.apply_rope(k, sin, cos)
        att = layers.blocked_causal_attention(q, k, v)
        x = x + layers.project_out(sp["attn"], att, x.dtype)
        h = layers.rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(sp["mlp"], h, cfg)
        return x, (convs, ssds, k, v)

    gfn = jax.checkpoint(group) if parallel.remat != "none" else group
    for g in range(_n_inv(cfg)):
        x, (convs, ssds, k, v) = gfn(x, _group_params(params, cfg, g))
        conv_all.append(convs)
        ssd_all.append(ssds)
        k_all.append(k)
        v_all.append(v)

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x[:, -1:], cfg)
    pad = ((0, 0), (0, 0), (0, 32), (0, 0), (0, 0))   # decode headroom
    cache = {
        "conv": jnp.concatenate(conv_all, 0),
        "ssd": jnp.concatenate(ssd_all, 0),
        "k": jnp.pad(jnp.stack(k_all, 0), pad),
        "v": jnp.pad(jnp.stack(v_all, 0), pad),
        "length": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


def zamba_decode_step(params, cache, tokens, cfg: ModelConfig,
                      unroll: bool = False):
    """O(1) SSM state + per-invocation full-length attention KV caches."""
    b = tokens.shape[0]
    x = common.embed_tokens(params, tokens, cfg)
    pos = cache["length"]
    sp = params["shared"]
    ninv, every = _n_inv(cfg), max(cfg.attn_every, 1)
    max_len = cache["k"].shape[2]
    bidx = jnp.arange(b)
    slot = jnp.minimum(pos, max_len - 1)

    new_conv, new_ssd, new_k, new_v = [], [], [], []
    for g in range(ninv):
        gp = _group_params(params, cfg, g)
        conv_g = jax.lax.dynamic_slice_in_dim(cache["conv"], g * every, every, 0)
        ssd_g = jax.lax.dynamic_slice_in_dim(cache["ssd"], g * every, every, 0)

        def body(x, xs):
            lp, conv_l, ssd_l = xs
            x, conv_l, ssd_l = mamba_decode_step(lp, x, cfg, conv_l, ssd_l)
            return x, (conv_l, ssd_l)

        x, (conv_g, ssd_g) = common.scan_or_unroll(
            body, x, (gp, conv_g, ssd_g), unroll=unroll)
        new_conv.append(conv_g)
        new_ssd.append(ssd_g)

        # shared attention with this invocation's cache
        h = layers.rms_norm(x, sp["ln1"], cfg.norm_eps)
        q = layers.project_q(sp["attn"], h, cfg)
        k_new, v_new = layers.project_kv(sp["attn"], h, cfg)
        if cfg.rope_theta:
            sin, cos = layers.rope_tables(pos[:, None], cfg.resolved_head_dim,
                                          cfg.rope_theta)
            q = layers.apply_rope(q, sin, cos)
            k_new = layers.apply_rope(k_new, sin, cos)
        oh = jax.nn.one_hot(slot, max_len,
                            dtype=cache["k"].dtype)[:, :, None, None]
        k_g = cache["k"][g] * (1 - oh) + k_new[:, 0][:, None] * oh
        v_g = cache["v"][g] * (1 - oh) + v_new[:, 0][:, None] * oh
        att = layers.decode_attention(q, k_g, v_g, pos + 1)
        x = x + layers.project_out(sp["attn"], att, x.dtype)
        h = layers.rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + layers.mlp_block(sp["mlp"], h, cfg)
        new_k.append(k_g)
        new_v.append(v_g)

    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = common.lm_head(params, x, cfg)
    new_cache = {
        "conv": jnp.concatenate(new_conv, 0),
        "ssd": jnp.concatenate(new_ssd, 0),
        "k": jnp.stack(new_k, 0),
        "v": jnp.stack(new_v, 0),
        "length": cache["length"] + 1,
    }
    return logits, new_cache
