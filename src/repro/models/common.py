"""Shared model plumbing: def stacking for scan, embedding, LM head, loss."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import Annotated


def scan_or_unroll(body, carry, xs, unroll: bool = False):
    """lax.scan, or an equivalent python loop when unroll=True.

    The unrolled form exists for the dry-run's cost probes: XLA's
    cost_analysis counts a while-loop body ONCE (trip count not folded), so
    exact per-step FLOP/collective accounting lowers 1- and 2-layer unrolled
    probes and extrapolates (benchmarks/roofline.py).
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a, 0), *ys)
    else:
        ys = None
    return carry, ys


def stack_defs(defs, num: int, axis_name: str = "layers"):
    """Add a leading `num`-sized dim to every Annotated leaf (for lax.scan)."""
    return jax.tree.map(
        lambda a: Annotated((num,) + a.shape, a.dtype, (axis_name,) + a.logical),
        defs,
        is_leaf=lambda x: isinstance(x, Annotated),
    )


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so the vocab dim always shards over
    the 16-way model axis (whisper's 51865 is otherwise coprime with 16 and
    the logits replicate — 108 GiB/device at prefill_32k). Padded logits are
    masked to -inf in lm_head; labels never reference them."""
    return -(-cfg.vocab_size // 256) * 256


def embed_defs(cfg: ModelConfig) -> dict:
    v = padded_vocab(cfg)
    d = {
        "embed": Annotated(
            (v, cfg.d_model), cfg.param_dtype, ("vocab", "embed")
        ),
        "ln_f": Annotated((cfg.d_model,), cfg.param_dtype, (None,)),
    }
    if not cfg.tie_embeddings:
        d["unembed"] = Annotated(
            (cfg.d_model, v), cfg.param_dtype, ("embed", "vocab")
        )
    return d


def embed_tokens(params, tokens, cfg: ModelConfig):
    """Token embedding lookup.

    The table is sharded (vocab -> model axis, embed -> data axis): this is
    the DPMR sparse face's storage layout — parameter rows co-located with
    the devices that own data shards. GSPMD lowers the gather to either a
    vocab-dim all-gather of the table shard or a masked-partial + all-reduce
    (= distributeParameters); both are recorded in the dry-run collectives.
    """
    emb = jnp.take(params["embed"], tokens, axis=0)
    return emb.astype(jnp.dtype(cfg.dtype))


def lm_head(params, x, cfg: ModelConfig):
    table = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = jnp.einsum(
        "bsd,dv->bsv", x, table.astype(x.dtype),
        preferred_element_type=jnp.float32
    )
    v = logits.shape[-1]
    if v != cfg.vocab_size:
        # mask the padded vocab tail (see padded_vocab)
        mask = jnp.arange(v) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def cross_entropy(logits, labels, mask: jax.Array | None = None):
    """logits: (B, S, V) f32; labels: (B, S) int32. Returns mean nll."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def embed_init_scale(ann: Annotated) -> float:
    """Flat 0.02 init stddev (GPT-2 style): predictable activation scale for
    smoke tests at any width; full-scale params are never materialized (the
    dry-run uses ShapeDtypeStructs)."""
    return 0.02
