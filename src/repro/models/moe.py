"""Mixture-of-Experts FFN with group-limited top-k dispatch.

The dispatch is the DPMR sparse face applied to experts: experts are
"features", tokens are "samples", the top-k routing table is the inverted
index, and the (token -> expert buffer) shuffle is distributeParameters in
reverse (samples travel to parameter shards). Expert-capacity padding plays
the role of the paper's sub-feature sharding: it bounds the per-owner buffer
exactly like splitting a hot feature's sample list bounds an HDFS line.

Group-limited dispatch: tokens are split into groups of `group_size`; within
a group the dispatch tensor is (g, E, C) with C = g * k * cf / E, so its size
is g*k*cf per token (linear, not quadratic, in total tokens).

Sharding: expert weights carry the `experts` logical axis -> `model` mesh
axis when divisible (phi3.5: 16 experts over 16-way TP = pure EP; the
(group->expert) reshard lowers to an all-to-all). When E does not divide the
axis (mixtral: 8 over 16), experts replicate and the `ff` dim shards instead
(TP-MoE) — same FLOPs, different collective mix; both appear in the roofline.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.sharding import Annotated

GROUP_SIZE = 512


def _constrain_ep(x, e: int, spec_dims):
    """Expert-parallel sharding constraint (no-op outside a mesh or when E
    does not divide the model axis). spec_dims: tuple of axis names/None per
    dim. Forcing (group->data, expert->model) on the dispatch buffers makes
    GSPMD reshard with all-to-all-equivalent wire bytes instead of
    all-gathering the whole buffer (16x on phi3.5)."""
    import jax
    from jax.sharding import PartitionSpec as P

    try:
        mesh = compat.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        dims = []
        for i, ax in enumerate(spec_dims):
            if ax is None or ax not in mesh.axis_names or \
                    x.shape[i] % mesh.shape[ax] != 0:
                dims.append(None)
            else:
                dims.append(ax)
        return jax.lax.with_sharding_constraint(x, P(*dims))
    except Exception:
        return x


def moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pt = cfg.param_dtype
    return {
        "router": Annotated((d, e), pt, ("mlp_embed", None)),
        "wi_gate": Annotated((e, d, f), pt, ("experts", "mlp_embed", "ff")),
        "wi_up": Annotated((e, d, f), pt, ("experts", "mlp_embed", "ff")),
        "wo": Annotated((e, f, d), pt, ("experts", "ff", "mlp_embed")),
    }


def expert_capacity(cfg: ModelConfig, group_size: int) -> int:
    c = int(group_size * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_block(p, x, cfg: ModelConfig,
              group_size: int = GROUP_SIZE) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    g = min(group_size, b * s)
    assert (b * s) % g == 0, (b, s, g)
    ng = b * s // g
    cap = expert_capacity(cfg, g)

    xg = x.reshape(ng, g, d)
    logits = jnp.einsum("ngd,de->nge", xg, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (ng, g, E) f32

    gate_vals, idx = jax.lax.top_k(probs, k)                   # (ng, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert buffer
    sel = jax.nn.one_hot(idx, e, dtype=jnp.int32)              # (ng, g, k, E)
    flat = sel.reshape(ng, g * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (ng, g*k, E)
    keep = (pos < cap) & (flat > 0)
    # dispatch/combine tensors (ng, g*k, E, C)
    disp = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    gate_flat = gate_vals.reshape(ng, g * k)
    comb = disp * gate_flat[..., None, None].astype(x.dtype)
    # fold k back onto tokens: (ng, g, k, E, C) -> sum k -> (ng, g, E, C)
    disp = disp.reshape(ng, g, k, e, cap).sum(axis=2)
    comb = comb.reshape(ng, g, k, e, cap).sum(axis=2)

    # tokens -> expert buffers (the DPMR shuffle; resharding group->expert
    # ownership lowers to all-to-all under EP)
    xin = jnp.einsum("ngec,ngd->necd", disp, xg,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    xin = _constrain_ep(xin, e, ("data", "model", None, None))
    hg = jnp.einsum("necd,edf->necf", xin, p["wi_gate"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    hu = jnp.einsum("necd,edf->necf", xin, p["wi_up"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hu).astype(x.dtype)
    yo = jnp.einsum("necf,efd->necd", h, p["wo"].astype(x.dtype),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    yo = _constrain_ep(yo, e, ("data", "model", None, None))
    out = jnp.einsum("ngec,necd->ngd", comb, yo,
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # Switch-style load-balancing auxiliary loss
    density = jnp.mean(flat.astype(jnp.float32), axis=1)       # (ng, E)
    density_prob = jnp.mean(probs, axis=1)                     # (ng, E)
    aux = jnp.mean(jnp.sum(density * density_prob, axis=-1)) * (e * e / k)

    return out.reshape(b, s, d), aux
