"""Sparse serving subsystem: resident parameters, micro-batched requests.

    from repro.serve import (BatchingConfig, DPMRServeEngine,
                             HotCacheConfig)

`DPMRServeEngine` keeps a `DPMREngine`'s sharded state resident on the
mesh and streams concurrent requests through deadline-coalesced,
bucket-padded micro-batches (`serve/batching.py` +
`DPMREngine.predict_padded`), with a host-side Zipf-head parameter cache
(`serve/hot_cache.py`, built on `repro.core.hot_sharding`) answering
head-only requests without touching the sparse exchange. Architecture and
knob reference: docs/SERVING.md.
"""
from repro.serve.batching import BatchingConfig, MicroBatcher
from repro.serve.engine import DPMRServeEngine
from repro.serve.hot_cache import HotCacheConfig, HotFeatureCache
from repro.serve.metrics import ServeMetrics

__all__ = [
    "BatchingConfig",
    "DPMRServeEngine",
    "HotCacheConfig",
    "HotFeatureCache",
    "MicroBatcher",
    "ServeMetrics",
]
