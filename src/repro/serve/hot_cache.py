"""Host-side hot-feature parameter cache — the Zipf-head fast path.

The paper's §4 observation cuts both ways at serving time: under Zipf
traffic a handful of head features appears in almost every request. Those
features' parameters fit trivially on the serving host, so a request built
ENTIRELY of cached head features can be answered from a locally mirrored
dense slice — no micro-batch, no compiled step, no sparse exchange. Only
requests touching the Zipf tail go through the coalesced `predict_padded`
path.

This module is the serving consumer of `repro.core.hot_sharding`:

  feature_counts   histogram over a sliding window of recent request ids
  select_hot       picks the head set (frequency >= `threshold`, capped at
                   `max_hot`) exactly like the trainer's initParameters-time
                   statistic
  split_hot        classifies the selected ids against the MODEL's
                   replicated hot set, so the mirror gathers each value from
                   the right table (`state.hot` for model-hot features,
                   `state.cold` for owner-sharded ones)

Staleness contract (documented in docs/SERVING.md):

  - a hit is answered from the mirror only while the mirror is FRESH:
    at most `refresh_every` lookups old AND gathered at the engine's
    current `state.step`;
  - crossing either bound does not serve stale values — the next lookup
    refreshes the mirror first (counted in `cache_stale_refreshes` /
    `cache_step_refreshes`), then answers;
  - within freshness, a cached hit is bit-identical to the uncached sparse
    path: the mirror holds exact f32 parameter values and the hit compute
    runs the same `sum(vals * theta, axis=-1) -> sigmoid` as the device
    predict stage (tests/test_hot_sharding.py asserts equality).
"""
from __future__ import annotations

import collections
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpmr, hot_sharding
from repro.serve.metrics import ServeMetrics


@dataclasses.dataclass(frozen=True)
class HotCacheConfig:
    """Hot-cache knobs.

    max_hot:        mirror slots (select_hot cap) — the head-set size
    threshold:      minimum in-window frequency for a feature to be cached
    window:         sliding request window feeding feature_counts
    refresh_every:  staleness bound, in lookups: a mirror older than this
                    many served requests is refreshed before the next hit
    """

    max_hot: int = 256
    threshold: float = 0.001
    window: int = 512
    refresh_every: int = 256

    def __post_init__(self):
        if self.max_hot < 1:
            raise ValueError(f"max_hot must be >= 1: {self.max_hot}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1: {self.window}")
        if self.refresh_every < 1:
            raise ValueError(
                f"refresh_every must be >= 1: {self.refresh_every}")


@jax.jit
def _hit_predict(theta: jax.Array, vals: jax.Array) -> jax.Array:
    """The device predict stage's math on mirrored parameters: identical
    ops/dtypes (f32 row-sum then sigmoid), so a fresh hit is bit-identical
    to the sparse path."""
    return jax.nn.sigmoid(jnp.sum(vals * theta, axis=-1))


class HotFeatureCache:
    """Sliding-window hot-set mirror over a live `DPMREngine` state.

    Thread-safe: `observe`/`lookup` take an internal lock, so client
    threads and the flusher can share one cache. The mirror gathers values
    lazily (first lookup) and again whenever stale (see the module
    docstring's staleness contract).
    """

    def __init__(self, engine, config: HotCacheConfig | None = None,
                 metrics: ServeMetrics | None = None):
        self.engine = engine
        self.config = config or HotCacheConfig()
        self.metrics = metrics or ServeMetrics()
        self._lock = threading.Lock()
        self._window: collections.deque = collections.deque(
            maxlen=self.config.window)          # flat id arrays, one/request
        self._ids: np.ndarray | None = None     # sorted, INT_MAX padded
        self._vals: np.ndarray | None = None    # f32, aligned with _ids
        self._mirror_step = -1                  # engine step at last gather
        self._lookups_since_refresh = 0

    # -- observation & freshness --------------------------------------------

    def observe(self, ids: np.ndarray) -> None:
        """Feed one request's ids into the sliding frequency window."""
        with self._lock:
            self._window.append(np.asarray(ids, np.int32).reshape(-1))

    @property
    def staleness(self) -> int:
        """Lookups served since the mirror was last gathered."""
        with self._lock:
            return self._lookups_since_refresh

    @property
    def hot_ids(self) -> np.ndarray:
        """The currently mirrored feature ids (unpadded, sorted)."""
        with self._lock:
            if self._ids is None:
                return np.empty((0,), np.int32)
            return self._ids[self._ids != hot_sharding.INT_MAX].copy()

    def _fresh(self) -> bool:
        return (self._ids is not None
                and self._lookups_since_refresh < self.config.refresh_every
                and self._mirror_step == int(self.engine.state.step))

    # -- mirror refresh -----------------------------------------------------

    def refresh(self) -> None:
        """Re-derive the hot set from the window and re-gather its values."""
        with self._lock:
            self._refresh_locked()

    def _refresh_locked(self) -> None:
        state = self.engine.state
        f = dpmr.padded_features(self.engine.cfg, self.engine.mesh)
        if self._window:
            flat = np.concatenate(list(self._window))
        else:
            flat = np.empty((0,), np.int32)
        counts = hot_sharding.feature_counts(jnp.asarray(flat, jnp.int32), f)
        sel = hot_sharding.select_hot(counts, self.config.threshold,
                                      self.config.max_hot)
        valid = sel != hot_sharding.INT_MAX
        safe = jnp.where(valid, sel, 0)
        # model-hot features live in the replicated `hot` table, everything
        # else in the owner-sharded `cold` table — exactly the split the
        # device forward makes, so mirrored values are the exact f32
        # parameters a sparse predict would fetch
        hot_slot, is_hot, _ = hot_sharding.split_hot(safe, state.hot_ids)
        vals = jnp.where(is_hot, state.hot[jnp.clip(hot_slot, 0)],
                         state.cold[safe])
        vals = jnp.where(valid, vals, 0.0)
        self._ids = np.asarray(jax.device_get(sel))
        self._vals = np.asarray(jax.device_get(vals), np.float32)
        self._mirror_step = int(state.step)
        self._lookups_since_refresh = 0
        self.metrics.count("cache_refreshes")

    # -- the fast path ------------------------------------------------------

    def lookup(self, ids: np.ndarray,
               vals: np.ndarray) -> np.ndarray | None:
        """Answer a request from the mirror, or None (miss -> sparse path).

        A request hits iff every non-padding feature id is in the mirrored
        hot set. A stale mirror is refreshed FIRST (never answering from
        stale values), then consulted."""
        ids = np.asarray(ids, np.int32)
        vals = np.asarray(vals, np.float32)
        with self._lock:
            if not self._fresh():
                if self._ids is not None:
                    if self._mirror_step != int(self.engine.state.step):
                        self.metrics.count("cache_step_refreshes")
                    else:
                        self.metrics.count("cache_stale_refreshes")
                self._refresh_locked()
            self._lookups_since_refresh += 1
            table_ids, table_vals = self._ids, self._vals
        flat = ids.reshape(-1)
        pos = np.searchsorted(table_ids, flat)
        pos = np.clip(pos, 0, len(table_ids) - 1)
        found = (table_ids[pos] == flat) & (flat >= 0)
        if not np.all(found | (flat < 0)):
            self.metrics.count("cache_misses")
            return None
        theta = np.where(found, table_vals[pos], np.float32(0.0)) \
            .astype(np.float32).reshape(ids.shape)
        probs = np.asarray(_hit_predict(theta, vals))
        self.metrics.count("cache_hits")
        return probs
