"""`DPMRServeEngine` — resident-parameter, micro-batched sparse serving.

The paper's premise is that the parameter table is too large for one node
and must stay DISTRIBUTED; serving must therefore keep the sharded
`DPMRState` resident on the mesh and stream requests through the compiled
predict step, instead of re-materializing parameters per call. This engine
is that serving face:

    from repro.serve import DPMRServeEngine

    srv = DPMRServeEngine.from_checkpoint(cfg, mesh, "/ckpt/dir")
    fut = srv.submit(ids, vals)          # (r, K) padded-CSR rows
    probs = fut.result()                 # (r,) probabilities
    srv.stop()                           # drains the queue

Three layers under one object:

  MicroBatcher       (serve/batching.py) a thread-safe queue + deadline-
                     aware flusher: requests coalesce until `max_batch`
                     rows or `max_wait_ms`, whichever first.
  predict_padded     the flushed batch pads to a small ladder of bucketed
                     sizes, so the per-batch-size `StepFns` LRU cache gets
                     hits instead of recompiles under mixed request sizes.
  HotFeatureCache    (serve/hot_cache.py) requests made entirely of
                     Zipf-head features are answered from a host-mirrored
                     dense slice and never enter the queue at all.

Results come back as per-request futures, bit-identical to what
`engine.predict` would return for the same rows (hot-cache hits included,
while the mirror is fresh — see the staleness contract in
serve/hot_cache.py). All counters live on one `ServeMetrics`
(`srv.metrics_snapshot()`).

During serving, the flusher thread is the only caller into the wrapped
engine's compiled steps; don't train the same engine concurrently from
another thread (train between `stop()`/`start()` instead — the hot cache
notices the step change and refreshes itself).
"""
from __future__ import annotations

import concurrent.futures
import time
import warnings

import numpy as np

from repro.api.engine import DPMREngine
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import DPMRConfig
from repro.serve.batching import BatchingConfig, MicroBatcher
from repro.serve.hot_cache import HotCacheConfig, HotFeatureCache
from repro.serve.metrics import ServeMetrics


class DPMRServeEngine:
    """Resident-parameter serving over a live (or restored) `DPMREngine`.

    Parameters
    ----------
    engine:     the wrapped `DPMREngine`; its sharded state stays resident
                on the mesh for the lifetime of the server
    batching:   `BatchingConfig` (max_batch / max_wait_ms / pad buckets)
    hot_cache:  `HotCacheConfig`, or None to disable the Zipf-head fast
                path entirely
    start:      start the flusher immediately (default); with False, call
                `start()` before submitting
    """

    def __init__(self, engine: DPMREngine, *,
                 batching: BatchingConfig | None = None,
                 hot_cache: HotCacheConfig | None = HotCacheConfig(),
                 start: bool = True):
        self.engine = engine
        self.batching = batching or BatchingConfig()
        self.metrics = ServeMetrics()
        self._k = int(engine.cfg.max_features_per_sample)
        self.cache = None if hot_cache is None else HotFeatureCache(
            engine, hot_cache, self.metrics)
        self._batcher = MicroBatcher(self._predict_flush, self.batching,
                                     self.metrics)
        if start:
            self.start()

    @classmethod
    def from_checkpoint(cls, cfg: DPMRConfig, mesh, directory: str, *,
                        step: int | None = None,
                        **kw) -> "DPMRServeEngine":
        """Restore-into-serving: build an engine on `mesh`, restore the
        sparse checkpoint at `directory` into it, and serve it.

        Fails loudly when pointed at a non-sparse checkpoint (e.g. a dense
        LM checkpoint from `launch/train.py`) — the manifest must carry
        `kind == "dpmr_sparse"`, which `DPMREngine.save` writes."""
        ck = Checkpointer(directory)
        at = ck.latest_step() if step is None else step
        if at is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        import json
        import os
        with open(os.path.join(directory, f"step_{at:010d}",
                               "manifest.json")) as f:
            kind = json.load(f).get("extra", {}).get("kind")
        if kind != "dpmr_sparse":
            raise ValueError(
                f"{directory} step {at} is not a sparse DPMR checkpoint "
                f"(manifest kind={kind!r}); the sparse serving engine "
                "cannot serve a dense LM state — use the dense serve path "
                "for that")
        engine = DPMREngine(cfg, mesh)
        with warnings.catch_warnings():
            # serving never resumes the training data stream; the engine's
            # "checkpoint carries a data cursor but no loader" warning is
            # noise here (strategy/topk mismatch warnings still surface)
            warnings.filterwarnings("ignore", message=".*data cursor.*",
                                    category=RuntimeWarning)
            engine.restore(directory, step=step)
        return cls(engine, **kw)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "DPMRServeEngine":
        self._batcher.start()
        return self

    def stop(self) -> None:
        """Drain the queue (every accepted request is answered) and stop
        the flusher. Idempotent; the engine state stays resident, so
        `start()` serves again."""
        self._batcher.stop()

    def __enter__(self) -> "DPMRServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request path -------------------------------------------------------

    def submit(self, ids, vals) -> concurrent.futures.Future:
        """Queue one request of (r, K') sparse rows; K' <= the engine's
        max_features_per_sample (short rows are padded). Returns a Future
        of the (r,) probabilities. Thread-safe."""
        t0 = time.monotonic()
        ids, vals = self._conform(ids, vals)
        self.metrics.count("requests")
        self.metrics.count("samples", len(ids))
        if self.cache is not None:
            self.cache.observe(ids)
            probs = self.cache.lookup(ids, vals)
            if probs is not None:
                fut: concurrent.futures.Future = concurrent.futures.Future()
                fut.set_result(probs)
                self.metrics.record_latency(time.monotonic() - t0)
                return fut
        return self._batcher.submit(ids, vals)

    def predict(self, batch: dict) -> np.ndarray:
        """Synchronous convenience: submit the batch as ONE request (it
        still coalesces with concurrent traffic) and wait for its result."""
        return np.asarray(self.submit(batch["ids"], batch["vals"]).result())

    def _conform(self, ids, vals) -> tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(ids, np.int32)
        vals = np.asarray(vals, np.float32)
        if ids.ndim == 1:
            ids, vals = ids[None, :], vals[None, :]
        if ids.ndim != 2 or ids.shape != vals.shape:
            raise ValueError(
                f"request must be (rows, K) id/val pairs of one shape; got "
                f"ids {ids.shape} vals {vals.shape}")
        k = ids.shape[1]
        if k > self._k:
            raise ValueError(
                f"request has {k} features per sample but the engine "
                f"compiled for max_features_per_sample={self._k}")
        if k < self._k:
            pad = self._k - k
            ids = np.concatenate(
                [ids, np.full((len(ids), pad), -1, np.int32)], axis=1)
            vals = np.concatenate(
                [vals, np.zeros((len(vals), pad), np.float32)], axis=1)
        return ids, vals

    # -- flusher side -------------------------------------------------------

    def _predict_flush(self, ids: np.ndarray,
                       vals: np.ndarray) -> np.ndarray:
        """The MicroBatcher's predict_fn: one coalesced micro-batch through
        the bucket-padded compiled step (flusher thread only)."""
        n = len(ids)
        self.metrics.record_flush(
            n, self.engine.bucket_for(n, self.batching.buckets))
        return self.engine.predict_padded({"ids": ids, "vals": vals},
                                          self.batching.buckets)

    # -- introspection ------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return self._batcher.queue_depth

    def metrics_snapshot(self) -> dict:
        """Counters + latency percentiles + cache/batching stats, plus the
        engine-side compiled-entry count (the recompile-trap gauge)."""
        out = self.metrics.snapshot()
        out["compiled_step_fns"] = len(self.engine._fns)
        return out
