"""Deadline-aware micro-batch coalescing for the sparse serving engine.

Requests (small `{ids, vals}` row groups) enter a thread-safe queue; a
single flusher thread coalesces them into micro-batches and hands each
batch to a `predict_fn`. A batch flushes when either

  - the pending rows reach `max_batch` (a full batch), or
  - `max_wait_ms` has elapsed since the OLDEST pending request arrived
    (the deadline — a lone request never waits longer than the window), or
  - the batcher is stopping (drain: everything queued is still served).

Requests are atomic — a request's rows are never split across flushes, so
one oversized request can push a flush past `max_batch`; the bucket ladder
in `DPMREngine.predict_padded` absorbs that. Results are scattered back to
per-request `concurrent.futures.Future`s, and a `predict_fn` exception
fails every future in the batch rather than wedging the queue.

The flusher thread is the ONLY caller of `predict_fn`, so the engine
underneath never sees concurrent steps however many client threads submit.
"""
from __future__ import annotations

from collections.abc import Callable
import concurrent.futures
import dataclasses
import threading
import time
from typing import NamedTuple

import numpy as np

from repro.serve.metrics import ServeMetrics


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    """Coalescing knobs.

    max_batch:    flush as soon as this many rows are pending (the
                  throughput lever)
    max_wait_ms:  flush a partial batch this many ms after its oldest
                  request arrived (the latency lever; 0 = flush immediately,
                  i.e. no coalescing beyond what queues up during a step)
    buckets:      explicit pad ladder forwarded to `predict_padded`
                  (None = the engine's power-of-two-multiple-of-P ladder)
    """

    max_batch: int = 64
    max_wait_ms: float = 2.0
    buckets: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1: {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0: {self.max_wait_ms}")


class _Pending(NamedTuple):
    ids: np.ndarray                      # (r, K) int32
    vals: np.ndarray                     # (r, K) f32
    future: concurrent.futures.Future    # resolves to (r,) probabilities
    t_enqueue: float                     # time.monotonic() at submit


class MicroBatcher:
    """Thread-safe request queue + deadline-aware flusher thread.

    `predict_fn(ids (n,K), vals (n,K)) -> (n,) np.ndarray` runs on the
    flusher thread only. `start()` before submitting; `stop()` drains the
    queue (every accepted request still gets its result) and joins the
    thread. Usable as a context manager.
    """

    def __init__(self, predict_fn: Callable[[np.ndarray, np.ndarray],
                                            np.ndarray],
                 config: BatchingConfig | None = None,
                 metrics: ServeMetrics | None = None):
        self._predict_fn = predict_fn
        self.config = config or BatchingConfig()
        self.metrics = metrics or ServeMetrics()
        self._cond = threading.Condition()
        self._pending: list[_Pending] = []
        self._pending_rows = 0
        self._stopping = False
        self._thread: threading.Thread | None = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MicroBatcher":
        with self._cond:
            if self._thread is not None:
                raise RuntimeError("MicroBatcher already started")
            self._stopping = False
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="dpmr-serve-flusher")
            self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue (pending requests are flushed and answered),
        then stop the flusher. Idempotent; `submit` afterwards raises."""
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
            thread = self._thread
        if thread is not None:
            thread.join()
        with self._cond:
            self._thread = None

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client side --------------------------------------------------------

    def submit(self, ids: np.ndarray,
               vals: np.ndarray) -> concurrent.futures.Future:
        """Queue one request; returns a Future of its (r,) probabilities."""
        ids = np.asarray(ids)
        vals = np.asarray(vals)
        if ids.ndim != 2 or ids.shape != vals.shape:
            raise ValueError(
                f"request must be (rows, K) id/val pairs of one shape; got "
                f"ids {ids.shape} vals {vals.shape}")
        fut: concurrent.futures.Future = concurrent.futures.Future()
        with self._cond:
            if self._stopping or self._thread is None:
                raise RuntimeError(
                    "MicroBatcher is stopped; start() it before submitting")
            if self._pending and self._pending[0].ids.shape[1] != \
                    ids.shape[1]:
                raise ValueError(
                    f"request K={ids.shape[1]} differs from the pending "
                    f"batch's K={self._pending[0].ids.shape[1]}; conform "
                    "requests to one max_features_per_sample first (the "
                    "serve engine pads them)")
            self._pending.append(_Pending(ids, vals, fut, time.monotonic()))
            self._pending_rows += len(ids)
            self._cond.notify_all()
        return fut

    @property
    def queue_depth(self) -> int:
        """Requests currently waiting to be flushed."""
        with self._cond:
            return len(self._pending)

    # -- flusher side -------------------------------------------------------

    def _run(self) -> None:
        max_wait = self.config.max_wait_ms / 1e3
        while True:
            with self._cond:
                while not self._pending and not self._stopping:
                    self._cond.wait()
                if not self._pending:        # stopping with an empty queue
                    return
                # wait out the coalescing window (or a full batch, or stop)
                deadline = self._pending[0].t_enqueue + max_wait
                while (self._pending_rows < self.config.max_batch
                        and not self._stopping):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                # take whole requests until max_batch rows are on board
                # (at least one, even if it alone exceeds max_batch)
                take, rows = 0, 0
                while take < len(self._pending) and \
                        (take == 0 or rows + len(self._pending[take].ids)
                         <= self.config.max_batch):
                    rows += len(self._pending[take].ids)
                    take += 1
                batch, self._pending = (self._pending[:take],
                                        self._pending[take:])
                self._pending_rows -= rows
                if rows >= self.config.max_batch:
                    reason = "full"
                elif self._stopping:
                    reason = "drain"
                else:
                    reason = "deadline"
            self._flush(batch, rows, reason)

    def _flush(self, batch: list[_Pending], rows: int, reason: str) -> None:
        done = time.monotonic  # latency stamp after scatter, per request
        self.metrics.count(f"flush_{reason}")
        try:
            ids = np.concatenate([p.ids for p in batch])
            vals = np.concatenate([p.vals for p in batch])
            probs = np.asarray(self._predict_fn(ids, vals))
            if probs.shape != (rows,):
                raise ValueError(
                    f"predict_fn returned {probs.shape}, expected ({rows},)")
        except BaseException as e:  # noqa: B036 — futures must not wedge
            for p in batch:
                if not p.future.cancelled():
                    p.future.set_exception(e)
            return
        off = 0
        for p in batch:
            r = len(p.ids)
            if not p.future.cancelled():
                p.future.set_result(probs[off:off + r])
            self.metrics.record_latency(done() - p.t_enqueue)
            off += r
