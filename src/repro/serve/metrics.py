"""Thread-safe serving metrics: counters + latency/batch-size recorders.

One `ServeMetrics` instance is shared by the serve engine, its
`MicroBatcher`, and its `HotFeatureCache`; every component only ever calls
`count` / `record_latency` / `record_flush` under the metrics lock, so the
numbers stay consistent however many client threads are submitting.

`snapshot()` derives the headline serving numbers:

  latency_p50_ms / latency_p99_ms   request latency percentiles
                                    (submit -> result, hot-cache hits
                                    included at their near-zero cost)
  qps                               completed requests / wall seconds
                                    since construction (or `reset_clock`)
  batch_mean / padded_mean          flushed micro-batch row counts, raw vs
                                    after bucket padding
  padding_frac                      wasted rows the bucket ladder added
  hot_hit_rate                      cache_hits / (cache_hits + cache_misses)

Counter names written by the subsystem (all start at 0 and appear in the
snapshot once touched): requests, samples, flushes, flush_full,
flush_deadline, flush_drain, cache_hits, cache_misses, cache_refreshes,
cache_stale_refreshes, cache_step_refreshes.
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np


class ServeMetrics:
    """Counters + bounded reservoirs of latencies and flush sizes."""

    def __init__(self, max_samples: int = 100_000):
        self._lock = threading.Lock()
        self._counters: collections.Counter = collections.Counter()
        self._latencies: list[float] = []       # seconds, one per request
        self._flush_rows: list[int] = []        # raw rows per flushed batch
        self._flush_padded: list[int] = []      # rows after bucket padding
        self._max_samples = int(max_samples)
        self._t0 = time.monotonic()

    def reset_clock(self) -> None:
        """Restart the QPS wall clock (e.g. after warmup)."""
        with self._lock:
            self._t0 = time.monotonic()

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] += n

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            if len(self._latencies) < self._max_samples:
                self._latencies.append(float(seconds))

    def record_flush(self, rows: int, padded_rows: int) -> None:
        """One coalesced micro-batch left the queue for the device (the
        per-reason `flush_full`/`flush_deadline`/`flush_drain` counters are
        incremented by the MicroBatcher, which knows why it flushed)."""
        with self._lock:
            self._counters["flushes"] += 1
            if len(self._flush_rows) < self._max_samples:
                self._flush_rows.append(int(rows))
                self._flush_padded.append(int(padded_rows))

    def snapshot(self) -> dict:
        """Point-in-time copy: raw counters + derived percentiles/rates."""
        with self._lock:
            counters = dict(self._counters)
            lat = np.asarray(self._latencies, np.float64)
            rows = np.asarray(self._flush_rows, np.float64)
            padded = np.asarray(self._flush_padded, np.float64)
            elapsed = time.monotonic() - self._t0
        out = dict(counters)
        if lat.size:
            out["latency_p50_ms"] = float(np.percentile(lat, 50) * 1e3)
            out["latency_p99_ms"] = float(np.percentile(lat, 99) * 1e3)
            out["qps"] = float(lat.size / max(elapsed, 1e-9))
        if rows.size:
            out["batch_mean"] = float(rows.mean())
            out["padded_mean"] = float(padded.mean())
            tot = float(padded.sum())
            out["padding_frac"] = float((padded - rows).sum() / max(tot, 1.0))
        hits = counters.get("cache_hits", 0)
        misses = counters.get("cache_misses", 0)
        if hits + misses:
            out["hot_hit_rate"] = hits / (hits + misses)
        return out
