"""Pallas TPU kernel: fused top-k select+pack for the sparsified reduce.

The `topk_reduce` strategy's reverse shuffle (repro/api/strategies.py)
prepares its wire payload with a chain of five XLA ops over the (P, cap)
send buffer: compensate with the error-feedback residual, build a |value|
ranking key, `jax.lax.top_k`, two `take_along_axis` gathers to pack the
(value, id) pairs, and a `where` to bank the losers' residual. Each op is
an HBM round trip over the buffer. This kernel is the whole chain in ONE
pass: each grid step holds one destination row in VMEM, ranks its slots,
and emits the packed pairs plus the residual update without materializing
any intermediate.

Ranking is comparison-matrix style (the same MXU-shaped trick as
segment_sum's equality mask): rank[i] counts slots that beat slot i —
strictly larger key, or equal key at an earlier position. That total
order is exactly `jax.lax.top_k`'s (descending value, ties by position),
so the kernel's selection set and output ORDER are bit-identical to the
reference chain; packing is a one-hot matmul `vals_k[r] = sum_i comp[i] *
[rank[i] == r]` with exactly one live term per output slot, so no
floating-point reassociation happens anywhere. `k` must come from
`repro.optim.compression.topk_count` (the strategy passes it through) so
kernel and wire model cannot disagree.

The (cap, cap) comparison mask bounds the practical capacity: cap = 4096
is a 64 MB f32 mask, the VMEM ceiling of one grid step. The strategy seam
falls back to the XLA chain above `MAX_CAPACITY`; production capacities
(4x the mean slots-per-peer, core.dpmr.capacity) sit far below it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# largest per-(src,dst) capacity the one-row-per-grid-step layout handles
# before the (cap, cap) ranking mask outgrows VMEM; ops.select_pack and the
# strategy seam fall back to the XLA chain past this
MAX_CAPACITY = 4096


def _kernel(send_ref, ids_ref, carry_ref, vals_ref, idsk_ref, resid_ref,
            *, cap: int, k: int):
    ids = ids_ref[...]                                  # (1, cap) int32
    valid = ids >= 0
    comp = jnp.where(valid,
                     send_ref[...].astype(jnp.float32)
                     + carry_ref[...].astype(jnp.float32), 0.0)
    # dead slots rank below every live one (key -1 < |comp| >= 0); they are
    # picked only when a row has fewer than k live slots, and their id -1
    # no-ops at the owner — same convention as the XLA chain
    key = jnp.where(valid, jnp.abs(comp), -1.0)

    # rank[i] = #{j : key[j] > key[i], or key[j] == key[i] and j < i} —
    # jax.lax.top_k's total order (descending, ties by position), built as
    # a (cap, cap) comparison mask and reduced along the j axis
    key_t = key.reshape(cap, 1)                         # key[j] down rows
    jpos = jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 0)
    ipos = jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 1)
    beats = (key_t > key) | ((key_t == key) & (jpos < ipos))
    rank = jnp.sum(beats.astype(jnp.int32), axis=0).reshape(1, cap)

    selected = rank < k
    # residual update in the same pass: winners flush to zero, losers bank
    # their full compensated value (invalid slots are dropped by the
    # caller's scatter, their content is irrelevant but kept = comp = 0)
    resid_ref[...] = jnp.where(selected & valid, 0.0, comp).astype(
        resid_ref.dtype)

    # pack by rank: ranks are a permutation of 0..cap-1 (the order above is
    # total), so output slot r has exactly ONE source — the one-hot matmul
    # moves each winner without summing anything against anything
    rpos = jax.lax.broadcasted_iota(jnp.int32, (cap, k), 1)
    onehot = rank.reshape(cap, 1) == rpos               # (cap, k)
    ids_k = jnp.sum(jnp.where(onehot, ids.reshape(cap, 1), 0),
                    axis=0).reshape(1, k)
    # rows with < k live slots pack dead slots: emit id -1 explicitly
    # (the int32 sum above yields 0-filled columns only if a rank is
    # missing, which cannot happen; dead slots carry their own -1)
    vals_k = jnp.dot(comp, onehot.astype(jnp.float32),
                     preferred_element_type=jnp.float32)  # (1, k)
    idsk_ref[...] = ids_k.astype(idsk_ref.dtype)
    vals_ref[...] = jnp.where(ids_k >= 0, vals_k, 0.0).astype(
        vals_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def select_pack(send, ids, carry_slots, *, k: int, interpret: bool = True):
    """Fused compensate + rank-by-|magnitude| + pack for one (P, cap)
    destination buffer.

    send:        (P, cap) f32 per-destination gradient sums
    ids:         (P, cap) int32 global feature ids (-1 = empty slot)
    carry_slots: (P, cap) f32 error-feedback residual gathered per slot
                 (`carry[ids]`; the gather/scatter against the (F,) carry
                 stays outside — it is not blockable by destination row)
    k:           slots kept per destination; MUST be
                 `compression.topk_count(cap, frac)`

    Returns (vals_k (P, k) f32, ids_k (P, k) int32, residual (P, cap) f32)
    where residual is the per-slot carry update (0 for selected slots, the
    compensated value for losers), bit-identical to the XLA chain in
    `TopKReduceStrategy.reduce`.
    """
    p, cap = ids.shape
    if cap > MAX_CAPACITY:
        raise ValueError(
            f"select_pack capacity {cap} exceeds MAX_CAPACITY "
            f"{MAX_CAPACITY} (the (cap, cap) ranking mask would outgrow "
            "VMEM); use the XLA chain for this geometry")
    row = lambda i: (i, 0)  # noqa: E731
    return pl.pallas_call(
        functools.partial(_kernel, cap=cap, k=k),
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, cap), row),
            pl.BlockSpec((1, cap), row),
            pl.BlockSpec((1, cap), row),
        ],
        out_specs=[
            pl.BlockSpec((1, k), row),
            pl.BlockSpec((1, k), row),
            pl.BlockSpec((1, cap), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, k), jnp.float32),
            jax.ShapeDtypeStruct((p, k), jnp.int32),
            jax.ShapeDtypeStruct((p, cap), jnp.float32),
        ],
        interpret=interpret,
    )(send, ids, carry_slots)
