"""Pallas TPU kernels for the DPMR hot path, behind the `kernel_impl` seam.

Layout (the authoring contract lives in docs/KERNELS.md):
  ops.py              the seam: per-op dispatchers selecting the kernel
                      or its oracle from `impl` — strategies and step fns
                      import ONLY this module
  ref.py              pure-jnp oracles; the `impl="xla"` production path
                      and the bit-parity ground truth of every kernel
  sigmoid_grad.py     computeGradients map body (Alg. 6)
  select_pack.py      topk_reduce's fused compensate + rank + pack
  segment_sum.py      sorted per-feature run sums (the Alg. 6 combiner;
                      powers ops.owner_accumulate's pallas path)
  flash_attention.py  dense-face attention, reference-grade (no sparse-
                      path caller)

Tested by tests/test_kernels.py (interpret mode, CPU); priced by
benchmarks/kernel_microbench.py.
"""
