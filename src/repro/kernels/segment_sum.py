"""Pallas TPU kernel: segment-sum over SORTED feature ids (the DPMR reduce
combiner, Algorithm 6's combiner/reducer adapted to the MXU).

On Hadoop the combiner is a hash-aggregation; scatter-add is the XLA
equivalent but lowers to serialized scatter on TPU. The TPU-native trick:
with ids sorted, per-run sums are a *masked matmul* —
    run_total[i] = sum_j grads[j] * (ids[j] == ids[i])
computed blockwise on the MXU with an (Nb x Nb) equality mask, plus a scalar
carry between consecutive blocks (grid steps run sequentially on a TPU core,
so scratch persists across them).

Output convention (== ref.segment_sum_sorted_ref): each run's total is
emitted at the run's LAST slot; all other slots are 0. Emitting at the end
makes the carry one-directional: a block adds the carried partial of a run
that began earlier, and forwards its own trailing partial. The wrapper
provides each block with the next block's first id so "does my trailing run
continue?" is a local decision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, grads_ref, next_ref, out_ref, carry_id_ref,
            carry_sum_ref, *, nb: int):
    i = pl.program_id(0)
    ids = ids_ref[...]
    g = jnp.where(ids >= 0, grads_ref[...].astype(jnp.float32), 0.0)

    @pl.when(i == 0)
    def _init():
        carry_id_ref[0] = jnp.int32(-1)
        carry_sum_ref[0] = jnp.float32(0.0)

    carry_id = carry_id_ref[0]
    carry_sum = carry_sum_ref[0]

    # (Nb, Nb) equality mask -> per-element run totals via MXU matmul
    eq = (ids[:, None] == ids[None, :]) & (ids[:, None] >= 0)
    totals = jnp.dot(eq.astype(jnp.float32), g,
                     preferred_element_type=jnp.float32)
    # elements of the run continuing from previous blocks get the carry
    cont = (ids == carry_id) & (ids >= 0)
    totals = totals + jnp.where(cont, carry_sum, 0.0)

    # run ends: id differs from the next element (trailing: next block's 1st)
    idx = jax.lax.broadcasted_iota(jnp.int32, (nb,), 0)
    nxt = jnp.roll(ids, -1)
    next_first = next_ref[0]
    nxt = jnp.where(idx == nb - 1, next_first, nxt)
    is_end = (ids != nxt) & (ids >= 0)

    out_ref[...] = jnp.where(is_end, totals, 0.0).astype(out_ref.dtype)

    # forward the trailing partial if the last run continues
    last_id = ids[nb - 1]
    continues = (last_id >= 0) & (last_id == next_first)
    carry_id_ref[0] = jnp.where(continues, last_id, jnp.int32(-1))
    carry_sum_ref[0] = jnp.where(continues, totals[nb - 1], jnp.float32(0.0))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segment_sum_sorted(ids, grads, *, block: int = 256,
                       interpret: bool = True):
    """ids: (N,) int32 sorted ascending (negatives = padding, sorted LAST by
    the caller); grads: (N,) f32. Returns (N,) f32 with each run's total at
    the run's last slot, 0 elsewhere."""
    n = ids.shape[0]
    nb = min(block, n)
    if n % nb != 0:
        nb = n
    grid = n // nb
    # next block's first id, per block (-2 => nothing follows)
    next_ids = jnp.concatenate(
        [ids[nb::nb], jnp.full((1,), -2, ids.dtype)])
    return pl.pallas_call(
        functools.partial(_kernel, nb=nb),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((nb,), lambda i: (i,)),
            pl.BlockSpec((nb,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((nb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.int32),
            pltpu.SMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(ids, grads, next_ids)
