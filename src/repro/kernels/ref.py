"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def sigmoid_grad_ref(vals, theta, labels):
    """DPMR computeGradients map body (Algorithm 6).

    vals, theta: (B, K) f32 (0 at padded slots); labels: (B,) in {0, 1}.
    Returns (per-slot grads (B, K), probs (B,), nll (B,)).

    grad[b,k] = vals[b,k] * (sigma(logit_b) - y_b)   [d/dtheta of the NLL]
    """
    vals = vals.astype(jnp.float32)
    theta = theta.astype(jnp.float32)
    logits = jnp.sum(vals * theta, axis=-1)
    probs = jax.nn.sigmoid(logits)
    y = labels.astype(jnp.float32)
    grads = vals * (probs - y)[:, None]
    nll = -(y * jax.nn.log_sigmoid(logits)
            + (1 - y) * jax.nn.log_sigmoid(-logits))
    return grads, probs, nll


def segment_sum_sorted_ref(ids, grads):
    """DPMR reduce combiner: per-feature sums for SORTED ids.

    ids: (N,) int32 sorted ascending; any negative id means padding (padding
    sorts last upstream). Returns (N,) where each run's LAST position holds
    the full run sum and all other positions are 0.
    """
    valid = ids >= 0
    is_start = jnp.concatenate([jnp.ones((1,), bool), ids[1:] != ids[:-1]])
    is_start = is_start & valid
    is_end = jnp.concatenate([ids[:-1] != ids[1:], jnp.ones((1,), bool)])
    is_end = is_end & valid
    seg = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    g = jnp.where(valid, grads, 0.0)
    sums = jax.ops.segment_sum(g, jnp.clip(seg, 0), num_segments=ids.shape[0])
    return jnp.where(is_end, sums[jnp.clip(seg, 0)], 0.0)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """O(S^2) attention oracle. q: (B,Sq,H,D); k,v: (B,Skv,KH,D)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        skv = k.shape[1]
        mask = jnp.arange(sq)[:, None] + (skv - sq) >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
