"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

REFERENCE-ONLY module: nothing here is a production path. Every oracle is
the semantics contract its kernel is tested against (tests/test_kernels.py
sweeps interpret-mode kernels vs these), and `impl="jnp"`/`"xla"` in
`kernels.ops` dispatches HERE — that pure-XLA lowering is the default
production path on CPU/GPU and the fallback on TPU. The Pallas kernels
(`impl="pallas"`) are the TPU hot path; see docs/KERNELS.md for which
production call sites route to which kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def sigmoid_grad_ref(vals, theta, labels):
    """DPMR computeGradients map body (Algorithm 6).

    vals, theta: (B, K) f32 (0 at padded slots); labels: (B,) in {0, 1}.
    Returns (per-slot grads (B, K), probs (B,), nll (B,)).

    grad[b,k] = vals[b,k] * (sigma(logit_b) - y_b)   [d/dtheta of the NLL]
    """
    vals = vals.astype(jnp.float32)
    theta = theta.astype(jnp.float32)
    logits = jnp.sum(vals * theta, axis=-1)
    probs = jax.nn.sigmoid(logits)
    y = labels.astype(jnp.float32)
    grads = vals * (probs - y)[:, None]
    nll = -(y * jax.nn.log_sigmoid(logits)
            + (1 - y) * jax.nn.log_sigmoid(-logits))
    return grads, probs, nll


def segment_sum_sorted_ref(ids, grads):
    """DPMR reduce combiner: per-feature sums for SORTED ids.

    ids: (N,) int32 sorted ascending; any negative id means padding (padding
    sorts last upstream). Returns (N,) where each run's LAST position holds
    the full run sum and all other positions are 0.
    """
    valid = ids >= 0
    is_start = jnp.concatenate([jnp.ones((1,), bool), ids[1:] != ids[:-1]])
    is_start = is_start & valid
    is_end = jnp.concatenate([ids[:-1] != ids[1:], jnp.ones((1,), bool)])
    is_end = is_end & valid
    seg = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    g = jnp.where(valid, grads, 0.0)
    sums = jax.ops.segment_sum(g, jnp.clip(seg, 0), num_segments=ids.shape[0])
    return jnp.where(is_end, sums[jnp.clip(seg, 0)], 0.0)


def select_pack_ref(send, ids, carry_slots, *, k: int):
    """Fused top-k select+pack oracle — the exact XLA chain the
    `topk_reduce` strategy ran before the kernel existed.

    send, carry_slots: (P, cap) f32; ids: (P, cap) int32 (-1 = empty);
    k from `repro.optim.compression.topk_count`. Returns
    (vals_k (P, k), ids_k (P, k), residual (P, cap)) — see
    `select_pack.select_pack` for the semantics; this chain and the kernel
    must agree BIT-exactly (ranking order included).
    """
    from repro.optim import compression

    valid = ids >= 0
    comp = jnp.where(valid, send + carry_slots, 0.0)
    key = jnp.where(valid, jnp.abs(comp), -1.0)
    top_idx, top_mask = compression.topk_select(key, k)
    ids_k = jnp.take_along_axis(ids, top_idx, axis=1)
    vals_k = jnp.where(ids_k >= 0,
                       jnp.take_along_axis(comp, top_idx, axis=1), 0.0)
    residual = jnp.where(top_mask & valid, 0.0, comp)
    return vals_k, ids_k, residual


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """O(S^2) attention oracle. q: (B,Sq,H,D); k,v: (B,Skv,KH,D)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        skv = k.shape[1]
        mask = jnp.arange(sq)[:, None] + (skv - sq) >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
