"""Pallas TPU kernel: fused DPMR inference + per-feature gradient.

The computeGradients map body (paper Algorithm 6): per sufficient sample,
logit = <vals, theta>, p = sigmoid(logit), grad slot = vals * (p - y), plus
the per-sample NLL. One pass over the (B, K) sufficient-sample block held in
VMEM — on HBM-bound sparse workloads this is a single read of vals/theta and
a single write of grads (the jnp version materializes logits/probs between
HBM round trips).

Block layout: grid over batch tiles; each program holds a (Bb, K) tile of
vals/theta in VMEM (K is the padded features-per-sample, typically 64-256,
so a 256 x 256 f32 tile is 256 KB — well under VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, theta_ref, labels_ref, grads_ref, probs_ref, nll_ref):
    vals = vals_ref[...].astype(jnp.float32)
    theta = theta_ref[...].astype(jnp.float32)
    y = labels_ref[...].astype(jnp.float32)
    logits = jnp.sum(vals * theta, axis=-1)
    probs = jax.nn.sigmoid(logits)
    grads_ref[...] = (vals * (probs - y)[:, None]).astype(grads_ref.dtype)
    probs_ref[...] = probs.astype(probs_ref.dtype)
    # nll = -y*log_sigmoid(z) - (1-y)*log_sigmoid(-z)
    nll = -(y * jax.nn.log_sigmoid(logits)
            + (1.0 - y) * jax.nn.log_sigmoid(-logits))
    nll_ref[...] = nll.astype(nll_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sigmoid_grad(vals, theta, labels, *, block_b: int = 256,
                 interpret: bool = True):
    """vals, theta: (B, K); labels: (B,). Returns (grads, probs, nll)."""
    b, k = vals.shape
    bb = min(block_b, b)
    if b % bb != 0:
        bb = b  # fall back to a single block for ragged batch sizes
    grid = (b // bb,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(vals, theta, labels)
