"""Pallas TPU kernel: blockwise causal flash attention (forward).

Grid (batch*heads, q_blocks, kv_blocks); the kv dimension is innermost so
the online-softmax running state (m, l, acc) lives in VMEM scratch across
kv steps (TPU grid steps execute sequentially per core). Causal skipping:
kv blocks entirely in the future contribute nothing — the whole body runs
under pl.when(kv_start <= q_end), which on real TPUs skips the compute
(this is where the jnp reference's masked-FLOP waste disappears).

GQA: k/v carry KH heads; the q-head -> kv-head mapping happens in the
BlockSpec index_map (h // group), so kv blocks are never materially
repeated — unlike the XLA path, which broadcasts kv to H heads.

Block shapes default to (128, 128): MXU-aligned, and the VMEM working set is
q(128xD) + k,v(128xD) + acc(128xD) + scores(128x128) ~ 0.5 MB for D=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, scale: float, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    run = (k_start <= q_start + bq - 1) if causal else (ik >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, Sq, H, D); k, v: (B, Skv, KH, D) with H % KH == 0.

    Returns (B, Sq, H, D) in q.dtype. Forward only — the training path uses
    the XLA blocked implementation (repro.models.layers); this kernel is the
    serving/prefill hot path and the roofline subject.
    """
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq:
        bq = sq
    if skv % bk:
        bk = skv

    # (B*H, S, D) layout; kv keeps KH heads, mapped via index_map
    qr = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * kh, skv, d)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * kh, skv, d)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        return ((bh // h) * kh + (bh % h) // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk,
                          scale=1.0 / math.sqrt(d), causal=causal),
        grid=(b * h, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
