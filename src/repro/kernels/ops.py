"""Jitted dispatch wrappers for the Pallas kernels — the `kernel_impl`
seam between the DPMR hot path and its two lowerings.

`impl` selects the backend (`KERNEL_IMPLS`):
  - "xla"               pure-jnp reference chain lowered by XLA — the
                        DEFAULT and the fallback on CPU/GPU backends
                        ("jnp" is the legacy spelling, kept as an alias)
  - "pallas"            real TPU lowering (pl.pallas_call, interpret=False)
  - "pallas_interpret"  kernel body executed in python on CPU — the
                        correctness/testing mode (bit-parity with "xla"
                        is asserted by tests/test_kernels.py)

Production call sites (see docs/KERNELS.md for the paper-algorithm map):
  - `sigmoid_grad`      computeGradients map body (core.dpmr step fns)
  - `select_pack`       topk_reduce's fused compensate+rank+pack
                        (api.strategies.TopKReduceStrategy.reduce)
  - `owner_accumulate`  the reverse-shuffle scatter-add, rebuilt as
                        sort + `segment_sum_sorted` run totals so owners
                        do ONE add per unique feature instead of one per
                        received slot (api.strategies reduce paths)
  - `flash_attention`   reference-grade only: retained for the dense-face
                        attention experiments, no sparse-path caller —
                        exercised by tests, not by any engine step

The knob threads end to end: `DPMRConfig.kernel_impl` (or the engine /
`make_step_fns` argument) -> `StrategyContext.kernel_impl` -> these
wrappers. Everything here is shape-polymorphic jax; no backend is probed
at import time.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import segment_sum as _ss
from repro.kernels import select_pack as _sp
from repro.kernels import sigmoid_grad as _sg

DEFAULT_IMPL = "xla"
KERNEL_IMPLS = ("xla", "jnp", "pallas", "pallas_interpret")


def normalize_impl(impl: str) -> str:
    """Canonical impl name: "xla" and "jnp" are the same (reference) path;
    unknown names raise instead of silently running the fallback."""
    if impl not in KERNEL_IMPLS:
        raise ValueError(
            f"unknown kernel_impl {impl!r}; expected one of {KERNEL_IMPLS}")
    return "xla" if impl == "jnp" else impl


def is_pallas(impl: str) -> bool:
    """True when `impl` routes to a Pallas kernel (real or interpreted)."""
    return normalize_impl(impl) in ("pallas", "pallas_interpret")


def sigmoid_grad(vals, theta, labels, *, impl: str = DEFAULT_IMPL,
                 block_b: int = 256):
    if not is_pallas(impl):
        return _ref.sigmoid_grad_ref(vals, theta, labels)
    return _sg.sigmoid_grad(vals, theta, labels, block_b=block_b,
                            interpret=(impl == "pallas_interpret"))


def segment_sum_sorted(ids, grads, *, impl: str = DEFAULT_IMPL,
                       block: int = 256):
    if not is_pallas(impl):
        return _ref.segment_sum_sorted_ref(ids, grads)
    return _ss.segment_sum_sorted(ids, grads, block=block,
                                  interpret=(impl == "pallas_interpret"))


def select_pack(send, ids, carry_slots, *, k: int, impl: str = DEFAULT_IMPL):
    """Fused top-k select+pack (see select_pack.py). Falls back to the XLA
    chain when the capacity exceeds the kernel's VMEM-bounded maximum, so
    the seam never changes semantics with geometry."""
    if not is_pallas(impl) or ids.shape[1] > _sp.MAX_CAPACITY:
        return _ref.select_pack_ref(send, ids, carry_slots, k=k)
    return _sp.select_pack(send, ids, carry_slots, k=k,
                           interpret=(impl == "pallas_interpret"))


def owner_accumulate(req_ids, grads, acc_local, base, *,
                     impl: str = DEFAULT_IMPL, block: int = 256):
    """The reverse-shuffle scatter-add, kernelized.

    XLA path: `core.sparse.owner_accumulate`'s scatter-add — one add per
    received (P, cap) slot, serialized scatter on TPU. Pallas path: sort
    the received slots by feature id (padding last — the same key trick as
    `route_build`), reduce each run to ONE total with the masked-matmul
    `segment_sum_sorted` combiner, and scatter-add run totals; the owner
    does one memory add per UNIQUE feature instead of one per slot.

    Semantics match the XLA path exactly for sums that are exactly
    representable (each feature's total is the same set of addends); for
    general f32 the in-run addition order differs (matmul reduction vs
    scatter order), a documented LSB-level tolerance —
    tests/test_kernels.py pins both.
    """
    if not is_pallas(impl):
        # late import: core.sparse is the routing layer above this one
        from repro.core import sparse
        return sparse.owner_accumulate(req_ids, grads, acc_local, base)
    ids = req_ids.reshape(-1)
    g = jnp.where(ids >= 0, grads.reshape(-1), 0.0)
    sort_key = jnp.where(ids >= 0, ids, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(sort_key, stable=True)
    key_s = sort_key[order]
    ids_s = jnp.where(key_s == jnp.iinfo(jnp.int32).max, -1, key_s)
    totals = _ss.segment_sum_sorted(
        ids_s, g[order], block=block,
        interpret=(impl == "pallas_interpret"))
    # run totals live at run ends, zeros elsewhere: scattering the whole
    # vector adds 0.0 at non-end slots (a no-op) and drops padding
    local = jnp.where(ids_s >= 0, ids_s - base, acc_local.shape[0])
    return acc_local.at[local].add(totals, mode="drop")


def flash_attention(q, k, v, *, causal: bool = True,
                    impl: str = DEFAULT_IMPL, block_q: int = 128,
                    block_k: int = 128):
    if not is_pallas(impl):
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=(impl == "pallas_interpret"))
