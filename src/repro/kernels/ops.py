"""Jitted dispatch wrappers for the Pallas kernels.

`impl` selects the backend:
  - "pallas"            real TPU lowering (pl.pallas_call, interpret=False)
  - "pallas_interpret"  kernel body executed in python on CPU (correctness)
  - "jnp"               the pure-jnp oracle from ref.py

This container is CPU-only, so the default everywhere is the oracle or the
interpreted kernel; on a TPU deployment `impl="pallas"` is the hot path.
"""
from __future__ import annotations


from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import segment_sum as _ss
from repro.kernels import sigmoid_grad as _sg

DEFAULT_IMPL = "jnp"


def sigmoid_grad(vals, theta, labels, *, impl: str = DEFAULT_IMPL,
                 block_b: int = 256):
    if impl == "jnp":
        return _ref.sigmoid_grad_ref(vals, theta, labels)
    return _sg.sigmoid_grad(vals, theta, labels, block_b=block_b,
                            interpret=(impl == "pallas_interpret"))


def segment_sum_sorted(ids, grads, *, impl: str = DEFAULT_IMPL,
                       block: int = 256):
    if impl == "jnp":
        return _ref.segment_sum_sorted_ref(ids, grads)
    return _ss.segment_sum_sorted(ids, grads, block=block,
                                  interpret=(impl == "pallas_interpret"))


def flash_attention(q, k, v, *, causal: bool = True,
                    impl: str = DEFAULT_IMPL, block_q: int = 128,
                    block_k: int = 128):
    if impl == "jnp":
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=(impl == "pallas_interpret"))
