"""Static analysis over the DPMR strategy registry and compiled steps.

The paper's headline accounting is communication volume: every loop pays a
parameter-assignment shuffle and a gradient reduce, and each registered
`DistributionStrategy` justifies itself through a hand-written two-tier
`WireBytes` model. This subsystem makes those claims *machine-checked*
instead of trusted:

  trace.py      traces a strategy's `distribute` / `reduce` (and the
                engine's compiled `StepFns`) to jaxpr on ANALYTIC meshes —
                no devices needed — and extracts every collective with its
                axes, operand shapes, and dtypes.
  wire.py       classifies each extracted collective's bytes-received-per-
                device onto the ICI / DCN tiers of a `StrategyContext`.
  contracts.py  the lint rules: wire-model cross-check, lossy-strategy
                carry lifecycle, exact fallback on the accumulate path,
                multi-pod outer-tier liveness, donation audit.
  audit.py      `python -m repro.analysis.audit` — runs the rules over the
                whole registry and emits a machine-readable report;
                `scripts/check.sh` and CI run it as a hard gate.

See docs/ANALYSIS.md for what each rule proves and how to read a report.
"""
from repro.analysis.audit import AuditContext, audit_registry, build_contexts
from repro.analysis.contracts import Finding, check_strategy
from repro.analysis.trace import (
    Collective,
    StrategyTrace,
    collect_collectives,
    trace_jaxpr,
    trace_strategy,
)
from repro.analysis.wire import collective_wire, wire_total

__all__ = [
    "AuditContext",
    "Collective",
    "Finding",
    "StrategyTrace",
    "audit_registry",
    "build_contexts",
    "check_strategy",
    "collect_collectives",
    "collective_wire",
    "trace_jaxpr",
    "trace_strategy",
    "wire_total",
]
