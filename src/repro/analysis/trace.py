"""Jaxpr tracing of distribution strategies on analytic meshes.

Every strategy method runs inside shard_map, so its collectives name mesh
axes (`jax.lax.all_to_all(x, ctx.axes, ...)`). To trace those bodies
WITHOUT devices we extend jax's axis environment with the analytic axis
sizes (`jax.core.extend_axis_env_nd`) and run `jax.make_jaxpr` on abstract
inputs — the jaxpr then records each collective primitive with its axis
names, operand shapes, and dtypes, for any geometry (a 512-chip two-pod
mesh traces fine on a CPU-only host).

`trace_strategy` produces the auditor's raw material: the collective list
of `distribute`, of the carry-advancing `reduce` path (SGD), and — for
stateful strategies — of the frozen-carry accumulate path, plus the
structural facts the contract rules consume (does `reduce` return a
`(grad, carry)` pair, is the carry passed through untouched on the
accumulate path).
"""
from __future__ import annotations

from collections.abc import Iterable, Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp

# collectives the wire model understands (see wire.py); anything else that
# smells like a collective is still EXTRACTED so the auditor can reject it
# as unmodeled instead of silently under-counting
KNOWN_COLLECTIVES = frozenset({
    "all_to_all", "all_gather", "reduce_scatter", "psum", "pmax", "pmin",
    "ppermute",
})


class Collective(NamedTuple):
    """One collective equation extracted from a jaxpr."""

    prim: str                      # primitive name ("all_to_all", ...)
    axes: tuple[str, ...]          # mesh axes the collective runs over
    shapes: tuple[tuple[int, ...], ...]   # per-operand (per-device) shapes
    dtypes: tuple[str, ...]        # per-operand dtypes
    out_shapes: tuple[tuple[int, ...], ...]
    out_dtypes: tuple[str, ...]

    @property
    def signature(self) -> tuple:
        """Hashable identity used for signature pinning / set comparison."""
        return (self.prim, self.axes, self.shapes, self.dtypes)

    @property
    def in_bytes(self) -> int:
        """Total bytes of the per-device operand buffers."""
        return sum(_nbytes(s, d) for s, d in zip(self.shapes, self.dtypes,
                                                 strict=True))

    @property
    def out_bytes(self) -> int:
        return sum(_nbytes(s, d) for s, d in zip(self.out_shapes,
                                                 self.out_dtypes,
                                                 strict=True))

    def describe(self) -> str:
        ops = ", ".join(f"{d}{list(s)}" for s, d in
                        zip(self.shapes, self.dtypes, strict=True))
        return f"{self.prim}[{','.join(self.axes) or '·'}]({ops})"


def _nbytes(shape: tuple[int, ...], dtype: str) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize


def _axis_tuple(axis_name) -> tuple[str, ...]:
    if axis_name is None:
        return ()
    if isinstance(axis_name, (tuple, list)):
        return tuple(str(a) for a in axis_name)
    return (str(axis_name),)


def trace_jaxpr(fn, axis_sizes: dict, *avals):
    """`jax.make_jaxpr(fn)(*avals)` under an analytic axis environment.

    `axis_sizes` maps mesh axis name -> size; the environment makes
    `axis_index` / `all_to_all` / ... traceable without any devices.
    `avals` are `jax.ShapeDtypeStruct` pytrees.
    """
    with jax.core.extend_axis_env_nd(tuple(axis_sizes.items())):
        return jax.make_jaxpr(fn)(*avals)


def _eval_shape(fn, axis_sizes: dict, *avals):
    with jax.core.extend_axis_env_nd(tuple(axis_sizes.items())):
        return jax.eval_shape(fn, *avals)


def _subjaxprs(eqn) -> Iterable:
    for v in eqn.params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.core.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jax.core.Jaxpr):
                    yield x


def collect_collectives(jaxpr) -> list[Collective]:
    """Recursively extract collective eqns (incl. pjit/scan/shard_map
    sub-jaxprs) from a Jaxpr or ClosedJaxpr."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out: list[Collective] = []

    def walk(jpr):
        for eqn in jpr.eqns:
            name = eqn.primitive.name
            if name in KNOWN_COLLECTIVES or name.startswith("p") and \
                    "axis_name" in eqn.params:
                axes = _axis_tuple(eqn.params.get("axis_name",
                                                  eqn.params.get("axes")))
                if name == "psum" and "axes" in eqn.params:
                    axes = _axis_tuple(eqn.params["axes"])
                if eqn.params.get("axis_index_groups") is not None:
                    # built-ins never use groups; record under a distinct
                    # prim name so the wire model rejects it explicitly
                    name = name + "[grouped]"
                out.append(Collective(
                    prim=name, axes=axes,
                    shapes=tuple(tuple(v.aval.shape) for v in eqn.invars),
                    dtypes=tuple(str(v.aval.dtype) for v in eqn.invars),
                    out_shapes=tuple(tuple(v.aval.shape)
                                     for v in eqn.outvars),
                    out_dtypes=tuple(str(v.aval.dtype)
                                     for v in eqn.outvars)))
            for sub in _subjaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    return out


class StrategyTrace(NamedTuple):
    """Everything the contract rules need to know about one strategy on one
    analytic geometry."""

    distribute: tuple[Collective, ...]    # forward (theta shuffle) path
    reduce: tuple[Collective, ...]        # carry-advancing reduce (SGD path)
    accumulate: tuple[Collective, ...] | None  # frozen-carry path (stateful)
    stateful: bool                        # init_carry returned an array
    carry_1d_f32: bool | None             # carry is 1-D float32
    reduce_pair: bool | None              # reduce returned (grad, carry)
    carry_aval_preserved: bool | None     # returned carry aval == input
    carry_passthrough: bool | None        # accumulate path returns the
    #                                       carry INVAR itself (jaxpr-level
    #                                       proof it is untouched)
    wire_dtypes_accumulate: tuple[str, ...] | None  # dtypes on the wire
    #                                       on the accumulate path
    fwd_overflow: bool = False            # distribute's fwd dict carries a
    #                                       scalar int32 "overflow"


def batch_elems(ctx) -> int:
    """Analytic per-device flat feature-slot count used for tracing.

    Large enough that hier_a2a's inner capacity min(n, cap*Po) never
    clamps — the wire models are stated for the unclamped regime."""
    return max(256, 2 * ctx.capacity * max(ctx.outer_shards, 1))


def trace_strategy(strategy, ctx, axis_sizes: dict,
                   n: int | None = None) -> StrategyTrace:
    """Trace `strategy` on the analytic geometry (`ctx`, `axis_sizes`).

    `ctx` must carry REAL axis names (ctx.axes) matching `axis_sizes`;
    `n` is the flat per-device feature-slot count (ids/grads length),
    defaulting to `batch_elems(ctx)`.
    """
    n = batch_elems(ctx) if n is None else n
    cold = jax.ShapeDtypeStruct((ctx.block_size,), jnp.float32)
    ids = jax.ShapeDtypeStruct((n,), jnp.int32)
    grads = jax.ShapeDtypeStruct((n,), jnp.float32)

    # -- forward ------------------------------------------------------------
    def dist(cold_loc, cold_ids):
        return strategy.distribute(ctx, cold_loc, cold_ids)

    theta_fwd = _eval_shape(dist, axis_sizes, cold, ids)
    _, fwd_avals = theta_fwd
    ov = fwd_avals.get("overflow") if isinstance(fwd_avals, dict) else None
    fwd_overflow = (ov is not None and tuple(ov.shape) == ()
                    and ov.dtype == jnp.int32)
    dist_ops = tuple(collect_collectives(
        trace_jaxpr(dist, axis_sizes, cold, ids)))

    carry_aval = None
    stateful = False
    carry_1d_f32 = None
    with jax.core.extend_axis_env_nd(tuple(axis_sizes.items())):
        carry0 = strategy.init_carry(ctx)
    if carry0 is not None:
        stateful = True
        carry_aval = jax.ShapeDtypeStruct(tuple(carry0.shape),
                                          carry0.dtype)
        carry_1d_f32 = (carry0.ndim == 1
                        and carry0.dtype == jnp.float32)

    # -- reduce (both carry modes for stateful strategies) ------------------
    def make_reduce(accumulating: bool):
        if not stateful:
            def red(cold_loc, g, fwd):
                return strategy.reduce(ctx, cold_loc, g, fwd)
            return red

        def red(carry, cold_loc, g, fwd):
            # carry FIRST so its jaxpr invar index is fixed at 0 — the
            # passthrough proof below compares outvars against invars[0]
            return strategy.reduce(
                ctx, cold_loc, g,
                {**fwd, "carry": carry, "accumulate": accumulating})
        return red

    reduce_pair = None
    carry_preserved = None
    if stateful:
        out_avals = _eval_shape(make_reduce(False), axis_sizes,
                                carry_aval, cold, grads, fwd_avals)
        reduce_pair = (isinstance(out_avals, tuple) and len(out_avals) == 2)
        if reduce_pair:
            carry_preserved = (
                tuple(out_avals[1].shape) == tuple(carry_aval.shape)
                and out_avals[1].dtype == carry_aval.dtype)
        red_jpr = trace_jaxpr(make_reduce(False), axis_sizes,
                              carry_aval, cold, grads, fwd_avals)
        reduce_ops = tuple(collect_collectives(red_jpr))

        acc_jpr = trace_jaxpr(make_reduce(True), axis_sizes,
                              carry_aval, cold, grads, fwd_avals)
        acc_ops = tuple(collect_collectives(acc_jpr))
        # the accumulate path must leave the carry untouched; at jaxpr
        # level that means the second output IS the carry input variable
        outvars = acc_jpr.jaxpr.outvars
        invars = acc_jpr.jaxpr.invars
        passthrough = len(outvars) >= 2 and outvars[-1] is invars[0]
        wire_dtypes = tuple(sorted({d for c in acc_ops for d in c.dtypes}))
        return StrategyTrace(
            distribute=dist_ops, reduce=reduce_ops, accumulate=acc_ops,
            stateful=True, carry_1d_f32=carry_1d_f32,
            reduce_pair=reduce_pair, carry_aval_preserved=carry_preserved,
            carry_passthrough=passthrough,
            wire_dtypes_accumulate=wire_dtypes, fwd_overflow=fwd_overflow)

    out_aval = _eval_shape(make_reduce(False), axis_sizes,
                           cold, grads, fwd_avals)
    reduce_pair = isinstance(out_aval, tuple)
    red_jpr = trace_jaxpr(make_reduce(False), axis_sizes,
                          cold, grads, fwd_avals)
    reduce_ops = tuple(collect_collectives(red_jpr))
    return StrategyTrace(
        distribute=dist_ops, reduce=reduce_ops, accumulate=None,
        stateful=False, carry_1d_f32=None, reduce_pair=reduce_pair,
        carry_aval_preserved=None, carry_passthrough=None,
        wire_dtypes_accumulate=None, fwd_overflow=fwd_overflow)


def signature_multiset(ops: Sequence[Collective]) -> tuple:
    """Order-independent, hashable multiset of collective signatures."""
    return tuple(sorted(c.signature for c in ops))
