"""Bytes-received-per-device models for extracted collectives, split by
mesh tier (ICI vs DCN).

The convention matches the strategies' declared `WireBytes`: count the
bytes a device RECEIVES over a wire, attributed per sending peer — a
participant's own chunk never leaves the chip and is never counted. For a
collective over axes `A` with `n` participants, the peers sharing this
device's outer (pod) coordinate number `n_in` (the product of the sizes of
the inner axes in `A`), so `n_in - 1` remote peers are reached over ICI
and `n - n_in` over DCN.

Per primitive (tiled or not, `B` = total per-device buffer bytes):

  all_to_all      each peer contributes one `B/n` chunk:
                  ICI `(n_in-1) * B/n`, DCN `(n-n_in) * B/n`.
  all_gather      each peer's whole block (`B` = operand bytes) arrives:
                  ICI `(n_in-1) * B`, DCN `(n-n_in) * B`.
  reduce_scatter  each peer contributes one result-sized chunk
                  (`B` = result bytes): ICI `(n_in-1) * B`, DCN
                  `(n-n_in) * B`.
  psum/pmax/pmin  modeled as ring reduce-scatter + all_gather:
                  2 x the reduce_scatter cost of an operand-bytes/n chunk.
                  (Algorithm-dependent; XLA may lower differently, but
                  this is the standard analytic bound benchmarks use.)
  ppermute        one peer's buffer; attributed to DCN iff the permutation
                  axis set touches an outer axis (conservative).

Anything else (grouped collectives, unknown primitives) has NO model —
`collective_wire` raises, and the auditor turns that into a hard finding
instead of silently under-counting a strategy's wire claim.
"""
from __future__ import annotations

from collections.abc import Iterable, Mapping

from repro.analysis.trace import Collective
from repro.api.strategies import WireBytes


class UnmodeledCollectiveError(ValueError):
    """A collective the wire model cannot attribute (see wire.py docs)."""


def _group_sizes(c: Collective, axis_sizes: Mapping[str, int],
                 outer_axes: Iterable[str]) -> tuple[int, int]:
    """(n, n_in): participants in the collective's group, and how many of
    them share this device's outer (pod) coordinate."""
    outer = set(outer_axes)
    n = n_in = 1
    for a in c.axes:
        try:
            s = int(axis_sizes[a])
        except KeyError:
            raise UnmodeledCollectiveError(
                f"{c.describe()}: axis {a!r} not in the analytic mesh "
                f"{dict(axis_sizes)}") from None
        n *= s
        if a not in outer:
            n_in *= s
    return n, n_in


def collective_wire(c: Collective, axis_sizes: Mapping[str, int],
                    outer_axes: Iterable[str]) -> WireBytes:
    """Bytes received per device for one extracted collective."""
    n, n_in = _group_sizes(c, axis_sizes, outer_axes)
    if n == 1:
        return WireBytes(inner=0, outer=0)
    if c.prim == "all_to_all":
        chunk = c.in_bytes // n
        return WireBytes(inner=(n_in - 1) * chunk,
                         outer=(n - n_in) * chunk)
    if c.prim == "all_gather":
        return WireBytes(inner=(n_in - 1) * c.in_bytes,
                         outer=(n - n_in) * c.in_bytes)
    if c.prim == "reduce_scatter":
        return WireBytes(inner=(n_in - 1) * c.out_bytes,
                         outer=(n - n_in) * c.out_bytes)
    if c.prim in ("psum", "pmax", "pmin"):
        chunk = c.in_bytes // n
        return WireBytes(inner=2 * (n_in - 1) * chunk,
                         outer=2 * (n - n_in) * chunk)
    if c.prim == "ppermute":
        crosses = n != n_in
        return WireBytes(inner=0 if crosses else c.in_bytes,
                         outer=c.in_bytes if crosses else 0)
    raise UnmodeledCollectiveError(
        f"no wire model for extracted collective {c.describe()}")


def wire_total(ops: Iterable[Collective], axis_sizes: Mapping[str, int],
               outer_axes: Iterable[str]) -> WireBytes:
    """Sum of `collective_wire` over `ops` (both tiers)."""
    inner = outer = 0
    for c in ops:
        wb = collective_wire(c, axis_sizes, outer_axes)
        inner += wb.inner
        outer += wb.outer
    return WireBytes(inner=inner, outer=outer)
