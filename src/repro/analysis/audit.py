"""`python -m repro.analysis.audit` — prove the strategy registry's claims.

For every registered strategy x every analytic context (single device,
8-device pod, (2,4) multi-pod, the (2,16,16) production geometry) the audit
traces `distribute`/`reduce` to jaxpr (no devices needed), attributes every
extracted collective's bytes onto the ICI/DCN tiers, cross-checks the total
against the declared `bytes_per_device` WireBytes, and runs the contract
rules in `contracts.py`. It then compiles real `StepFns` on the host mesh
and audits the engine seam itself: donated buffers must stay aliased in the
lowering, the per-batch-size StepFns cache must hit, the elastic reshard
helper must reset stateful carries, and the compiled step's collectives
must re-verify the same wire totals end to end.

Exit status is 0 iff no findings; `--json PATH` writes the machine-readable
report (scripts/check.sh saves it as AUDIT_report.json for CI artifact
upload on failure). See docs/ANALYSIS.md.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import NamedTuple

from repro.analysis import trace as trace_mod
from repro.analysis.contracts import Finding, check_strategy
from repro.analysis.wire import UnmodeledCollectiveError, wire_total
from repro.api.strategies import StrategyContext, get_strategy, \
    list_strategies


class AuditContext(NamedTuple):
    """One analytic geometry the audit runs every strategy on."""

    name: str                     # report key ("pod8", "multipod", ...)
    ctx: StrategyContext          # geometry handed to the strategy
    axis_sizes: dict              # mesh axis name -> size (trace env)


def _make_ctx(axis_sizes: dict, outer_axes: tuple, *, block_size: int,
              capacity: int) -> StrategyContext:
    axes = tuple(axis_sizes)
    p = 1
    for s in axis_sizes.values():
        p *= int(s)
    po = 1
    for a in outer_axes:
        po *= int(axis_sizes[a])
    inner = tuple(a for a in axes if a not in outer_axes)
    return StrategyContext(axes=axes, num_shards=p, block_size=block_size,
                           capacity=capacity, inner_axes=inner,
                           outer_axes=outer_axes, outer_shards=po)


def build_contexts(*, block_size: int = 64, capacity: int = 16,
                   production: bool = True) -> tuple[AuditContext, ...]:
    """The default audit geometries.

    Degenerate, single-pod, multi-pod, and (optionally) the full
    `launch.mesh.make_production_mesh(multi_pod=True)` shape — all purely
    analytic, no devices touched.
    """
    specs = [
        ("1dev", {"data": 1, "model": 1}, ()),
        ("pod8", {"data": 2, "model": 4}, ()),
        ("multipod", {"pod": 2, "data": 4}, ("pod",)),
    ]
    if production:
        specs.append(
            ("production", {"pod": 2, "data": 16, "model": 16}, ("pod",)))
    return tuple(
        AuditContext(name=name,
                     ctx=_make_ctx(sizes, outer, block_size=block_size,
                                   capacity=capacity),
                     axis_sizes=sizes)
        for name, sizes, outer in specs)


def _wb_dict(wb) -> dict:
    return {"inner": int(wb.inner), "outer": int(wb.outer),
            "total": int(wb.inner) + int(wb.outer)}


def audit_registry(strategies=None, contexts=None, *,
                   engine_checks: bool = True) -> dict:
    """Run the full audit; returns the machine-readable report.

    `strategies`: names to audit (default: the whole registry).
    `contexts`: `AuditContext`s (default: `build_contexts()`).
    `engine_checks=False` skips the device-touching engine seam checks
    (useful from tests that only exercise the analytic rules).
    """
    names = list(strategies) if strategies is not None else list_strategies()
    contexts = tuple(contexts) if contexts is not None else build_contexts()
    findings: list[Finding] = []
    report: dict = {"strategies": {n: {} for n in names}}

    for actx in contexts:
        # exact (stateless) strategies' reduce signatures on THIS geometry
        # are the reference set for the A-EXACT accumulate-fallback rule
        traces: dict[str, trace_mod.StrategyTrace | None] = {}
        exact_sigs: dict[str, tuple] = {}
        for n in names:
            strat = get_strategy(n)
            try:
                tr = trace_mod.trace_strategy(strat, actx.ctx,
                                              actx.axis_sizes)
            except Exception:  # noqa: BLE001 - re-raised as TRACE finding
                tr = None
            traces[n] = tr
            if tr is not None and not tr.stateful:
                exact_sigs[n] = trace_mod.signature_multiset(tr.reduce)

        for n in names:
            strat = get_strategy(n)
            tr, fs = check_strategy(strat, actx.ctx, actx.axis_sizes,
                                    context_name=actx.name,
                                    exact_reduce_sigs=exact_sigs,
                                    tr=traces[n])
            findings.extend(fs)
            entry: dict = {"findings": [f.as_dict() for f in fs]}
            try:
                entry["declared"] = _wb_dict(
                    strat.bytes_per_device(actx.ctx))
            except Exception as e:  # noqa: BLE001
                entry["declared"] = f"error: {e}"
            if tr is not None:
                step_ops = tr.distribute + tr.reduce
                try:
                    entry["extracted"] = _wb_dict(wire_total(
                        step_ops, actx.axis_sizes, actx.ctx.outer_axes))
                except UnmodeledCollectiveError as e:
                    entry["extracted"] = f"unmodeled: {e}"
                entry["collectives"] = {
                    "distribute": [c.describe() for c in tr.distribute],
                    "reduce": [c.describe() for c in tr.reduce],
                }
                if tr.accumulate is not None:
                    entry["collectives"]["accumulate"] = [
                        c.describe() for c in tr.accumulate]
                entry["stateful"] = tr.stateful
            report["strategies"][n][actx.name] = entry

    if engine_checks:
        eng_findings, eng_report = _audit_engine(names)
        findings.extend(eng_findings)
        report["engine"] = eng_report

    report["ok"] = not findings
    report["num_findings"] = len(findings)
    report["findings"] = [f.as_dict() for f in findings]
    return report


# ---------------------------------------------------------------------------
# engine seam: compiled StepFns, donation, cache, elastic carry reset
# ---------------------------------------------------------------------------


def _audit_engine(names) -> tuple[list[Finding], dict]:
    """Device-touching checks on the real host mesh (works on 1 CPU)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import DPMRConfig
    from repro.core import dpmr
    from repro.launch.mesh import OUTER_AXES, make_host_mesh
    from repro.runtime.elastic import reshard_dpmr_state

    findings: list[Finding] = []
    report: dict = {"checks": []}

    def bad(rule, strategy, message):
        findings.append(Finding(rule=rule, strategy=strategy,
                                context="engine", message=message))

    def ok(check):
        report["checks"].append(check)

    mesh = make_host_mesh(1, 1)
    axis_sizes = {a: int(mesh.shape[a]) for a in mesh.axis_names}
    p = dpmr.num_shards(mesh)
    batch = p * 8

    for name in names:
        cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8,
                         distribution=name)
        try:
            fns = dpmr.make_step_fns(cfg, mesh, batch)
        except Exception as e:  # noqa: BLE001
            bad("E-COMPILE", name,
                f"make_step_fns failed on the host mesh: {e}")
            continue
        state = dpmr.init_state(cfg, mesh)
        k = cfg.max_features_per_sample
        b_sds = {
            "ids": jax.ShapeDtypeStruct((batch, k), jnp.int32),
            "vals": jax.ShapeDtypeStruct((batch, k), jnp.float32),
            "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
        }
        s_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)

        # E-DONATE: train_step/apply_update take state donated; the
        # lowering must record the aliasing (tf.aliasing_output) — a
        # donated buffer that is silently copied doubles peak memory of
        # the (F,)-sized table on real accelerators
        for fn_name, lowered in (
            ("train_step", fns.train_step.lower(s_sds, b_sds)),
            ("apply_update", fns.apply_update.lower(
                s_sds, s_sds.cold, s_sds.hot, 0.1)),
        ):
            if "tf.aliasing_output" not in lowered.as_text():
                bad("E-DONATE", name,
                    f"StepFns.{fn_name} lowering has no donated/aliased "
                    "buffers — the state must be donated "
                    "(donate_argnums) so updates reuse table memory")
            else:
                ok(f"{name}: {fn_name} donation aliased in lowering")

        # E-WIRE: the COMPILED train_step's collectives re-verify the
        # declared model end to end (host mesh geometry)
        try:
            jpr = fns.train_step.trace(s_sds, b_sds).jaxpr
            ops = [c for c in trace_mod.collect_collectives(jpr)
                   if c.prim != "psum"]  # hot-set/metrics psums are not
            #                              part of the strategy wire model
            extracted = wire_total(ops, axis_sizes, OUTER_AXES)
            declared = get_strategy(name).bytes_per_device(fns.ctx)
            if (int(declared.inner), int(declared.outer)) != (
                    extracted.inner, extracted.outer):
                bad("E-WIRE", name,
                    f"compiled train_step carries inner={extracted.inner} "
                    f"outer={extracted.outer} but the declared model says "
                    f"inner={declared.inner} outer={declared.outer}")
            else:
                ok(f"{name}: compiled train_step wire total matches "
                   "declared model")
        except Exception as e:  # noqa: BLE001
            bad("E-WIRE", name, f"compiled-step wire check failed: {e}")

        # E-RESET: the elastic reshard helper must return stateful
        # carries to zeros (a per-device residual is meaningless under a
        # different shard assignment)
        if get_strategy(name).init_carry(fns.ctx) is not None:
            dirty = state._replace(strat=jnp.ones_like(state.strat))
            fresh = reshard_dpmr_state(dirty, cfg, mesh)
            if float(jnp.abs(fresh.strat).max()) != 0.0:
                bad("E-RESET", name,
                    "runtime.elastic.reshard_dpmr_state must reset the "
                    "strategy carry to zeros")
            else:
                ok(f"{name}: elastic reshard resets the carry")

    # E-CACHE: the engine's per-batch-size StepFns cache must hit (a miss
    # means silent recompilation of every step on every call)
    from repro.api.engine import DPMREngine
    cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8)
    eng = DPMREngine(cfg, mesh)
    if eng.step_fns(batch) is not eng.step_fns(batch):
        bad("E-CACHE", "engine",
            "DPMREngine.step_fns(batch_size) recompiles on a repeat "
            "batch size instead of hitting the LRU cache")
    else:
        ok("engine: step_fns LRU cache hits on repeat batch size")
    return findings, report


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Static wire-model & contract audit of the DPMR "
                    "strategy registry (see docs/ANALYSIS.md).")
    ap.add_argument("--strategy", action="append", default=None,
                    help="audit only this strategy (repeatable; default: "
                         "the whole registry)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the device-touching engine-seam checks")
    ap.add_argument("--quiet", action="store_true",
                    help="print findings only, no per-strategy summary")
    args = ap.parse_args(argv)

    report = audit_registry(strategies=args.strategy,
                            engine_checks=not args.no_engine)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)

    if not args.quiet:
        for name, per_ctx in sorted(report["strategies"].items()):
            for ctx_name, entry in per_ctx.items():
                declared = entry.get("declared")
                extracted = entry.get("extracted")
                n_find = len(entry.get("findings", []))
                status = "ok" if n_find == 0 else f"{n_find} finding(s)"
                print(f"{name:18s} {ctx_name:10s} declared={declared} "
                      f"extracted={extracted} [{status}]")
    for f in report["findings"]:
        print(f"FINDING {f['rule']} [{f['strategy']} @ {f['context']}]: "
              f"{f['message']}", file=sys.stderr)
    n = report["num_findings"]
    print(f"audit: {len(report['strategies'])} strategies, "
          f"{n} finding(s) -> {'PASS' if report['ok'] else 'FAIL'}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
