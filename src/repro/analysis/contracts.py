"""The lint rules: what a registered strategy must prove on an analytic
geometry.

`check_strategy` runs every rule against one `(strategy, ctx)` pair and
returns `Finding`s. Rules (IDs appear in reports and test assertions):

  W-MODEL   every extracted collective has a wire model (wire.py) — an
            unmodeled collective would silently undercount the claim.
  W-MATCH   the declared `bytes_per_device` WireBytes equals the
            jaxpr-extracted bytes on BOTH tiers, for distribute + the
            carry-advancing reduce path. Exact strategies are exact by
            construction; the lossy built-ins are statically exact too
            (top-k sends exactly k pairs, int8 reduce sends exactly the
            padded block), so equality is required of everyone.
  W-OUTER   on a multi-pod context, declared AND extracted outer (DCN)
            bytes must be nonzero — a two-tier model that never crosses
            DCN on a 2-pod mesh is lying about one tier.
  W-SINGLE  on a single-pod context, declared and extracted outer must be
            exactly zero (nothing can cross a tier that does not exist).
  F-OVERFLOW `distribute` must return a fwd dict carrying a scalar int32
            "overflow" (the engine psums it into step metrics).
  C-CARRY   `init_carry` must return a 1-D float32 array (the engine
            stores it flat in `DPMRState.strat`), and `reduce` must then
            return `(grad, new_carry)` with the carry aval preserved;
            stateless strategies must return the bare gradient.
  A-FREEZE  on the accumulate path (`fwd["accumulate"]` set) a stateful
            strategy must return the carry INPUT itself — proven at jaxpr
            level (the output variable IS the input variable), not by
            value comparison.
  A-EXACT   the accumulate path must be exact: its collective signature
            multiset must equal the reduce-path signature multiset of one
            of the registry's exact (stateless) strategies on the same
            geometry, and must put only f32/int32 on the wire.

See docs/ANALYSIS.md for the rationale behind each rule.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.analysis import trace as trace_mod
from repro.analysis.wire import UnmodeledCollectiveError, wire_total
from repro.api.strategies import WireBytes

EXACT_WIRE_DTYPES = {"float32", "int32"}


class Finding(NamedTuple):
    """One rule violation (or the audit-level error that prevented a rule
    from running)."""

    rule: str        # rule ID ("W-MATCH", ...)
    strategy: str    # registered strategy name
    context: str     # analytic context name ("pod8", "multipod", ...)
    message: str     # human-readable diagnosis

    def as_dict(self) -> dict:
        return self._asdict()


def _fmt(wb: WireBytes) -> str:
    return f"inner={wb.inner} outer={wb.outer}"


def check_strategy(strategy, ctx, axis_sizes: dict, *,
                   context_name: str = "?",
                   exact_reduce_sigs: dict | None = None,
                   tr: trace_mod.StrategyTrace | None = None,
                   ) -> tuple[trace_mod.StrategyTrace | None, list[Finding]]:
    """Run every contract rule for one strategy on one analytic geometry.

    `exact_reduce_sigs` maps exact-strategy name -> reduce-path signature
    multiset on THIS geometry (from `trace.signature_multiset`); when None
    the A-EXACT rule is skipped. Pass `tr` to reuse an existing trace.
    Returns `(trace, findings)`; trace is None if tracing itself failed.
    """
    name = getattr(strategy, "name", type(strategy).__name__)
    findings: list[Finding] = []

    def bad(rule: str, message: str) -> None:
        findings.append(Finding(rule=rule, strategy=name,
                                context=context_name, message=message))

    if tr is None:
        try:
            tr = trace_mod.trace_strategy(strategy, ctx, axis_sizes)
        except Exception as e:  # noqa: BLE001 - any trace failure is a finding
            bad("TRACE", f"tracing failed: {type(e).__name__}: {e}")
            return None, findings

    try:
        declared = strategy.bytes_per_device(ctx)
        declared = WireBytes(inner=int(declared.inner),
                             outer=int(declared.outer))
    except Exception as e:  # noqa: BLE001
        bad("W-MATCH", f"bytes_per_device failed: {type(e).__name__}: {e}")
        declared = None

    step_ops = tr.distribute + tr.reduce
    try:
        extracted = wire_total(step_ops, axis_sizes, ctx.outer_axes)
    except UnmodeledCollectiveError as e:
        bad("W-MODEL", str(e))
        extracted = None

    if declared is not None and extracted is not None:
        if (declared.inner, declared.outer) != (extracted.inner,
                                                extracted.outer):
            ops = "; ".join(c.describe() for c in step_ops) or "none"
            bad("W-MATCH",
                f"declared {_fmt(declared)} but the traced collectives "
                f"carry {_fmt(extracted)} (ops: {ops})")
        multi_pod = ctx.outer_shards > 1
        if multi_pod:
            if declared.outer <= 0:
                bad("W-OUTER", "multi-pod context "
                    f"(outer_shards={ctx.outer_shards}) but the declared "
                    "wire model claims zero DCN bytes")
            if extracted.outer <= 0:
                bad("W-OUTER", "multi-pod context "
                    f"(outer_shards={ctx.outer_shards}) but no traced "
                    "collective crosses the outer tier")
        else:
            if declared.outer != 0 or extracted.outer != 0:
                bad("W-SINGLE", "single-pod context but nonzero outer "
                    f"bytes (declared {declared.outer}, extracted "
                    f"{extracted.outer})")

    if not tr.fwd_overflow:
        bad("F-OVERFLOW", "distribute's fwd dict must carry a scalar "
            'int32 "overflow" (0 when the strategy cannot drop)')

    if tr.stateful:
        if not tr.carry_1d_f32:
            bad("C-CARRY", "init_carry must return a 1-D float32 array "
                "(stored flat in DPMRState.strat)")
        if not tr.reduce_pair:
            bad("C-CARRY", "stateful reduce must return "
                "(grad, new_carry), got a bare value")
        elif not tr.carry_aval_preserved:
            bad("C-CARRY", "reduce's returned carry changes shape/dtype; "
                "the persistent carry aval must be preserved")
        if tr.reduce_pair and not tr.carry_passthrough:
            bad("A-FREEZE", 'on the accumulate path (fwd["accumulate"]) '
                "the carry must be returned untouched — the jaxpr output "
                "is not the carry input variable")
        if tr.accumulate is not None:
            dtypes = set(tr.wire_dtypes_accumulate or ())
            lossy = dtypes - EXACT_WIRE_DTYPES
            if lossy:
                bad("A-EXACT", "accumulate path puts lossy dtypes "
                    f"{sorted(lossy)} on the wire; it must fall back to "
                    "an exact reduce")
            if exact_reduce_sigs:
                acc_sig = trace_mod.signature_multiset(tr.accumulate)
                if acc_sig not in set(exact_reduce_sigs.values()):
                    ops = "; ".join(c.describe() for c in tr.accumulate) \
                        or "none"
                    bad("A-EXACT", "accumulate-path collectives match no "
                        "exact strategy's reduce path on this geometry "
                        f"(ops: {ops}; exact candidates: "
                        f"{sorted(exact_reduce_sigs)})")
    else:
        if tr.reduce_pair:
            bad("C-CARRY", "stateless strategy (init_carry -> None) must "
                "return the bare gradient from reduce, not a tuple")

    return tr, findings
