"""Gradient compression for the cross-pod (DCN) reduction.

The `pod` mesh axis crosses data-center network, ~10x slower than ICI; the
classic mitigation is compressed all-reduce with error feedback (1-bit
Adam / EF-SGD lineage). We implement int8 block-quantized all-reduce:

    q = round((g - e) / scale),  scale = max|g - e| / 127 per block
    g_hat = psum(q * scale) / n_pods
    e'    = (g - e) - dequant(q)          (error feedback, carried)

Used by the dense trainer via shard_map over ONLY the `pod` axis
(`axis_names={'pod'}`), leaving data/model sharding to GSPMD inside, and by
the sparse face's `compressed_reduce` distribution strategy
(repro/api/strategies.py), which quantizes the dense gradient reduce with
the same `quantize`/`dequantize` primitives and carries its error feedback
in `DPMRState.strat`. Wire-bytes drop 4x (f32->int8); error feedback keeps
SGD/Adam convergence (validated against uncompressed training in
tests/test_multidevice.py and benchmarks/strategy_hierarchy.py).

The top-k selection helpers (`topk_count`, `topk_select`, `topk_mask`)
live here too: the `topk_reduce` strategy builds its sparsified reverse
shuffle — and its wire model's k — out of exactly these primitives, with
the same error-feedback discipline as the quantizer above.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import compat

BLOCK = 2048


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. x: (N,) f32 (N % BLOCK == 0 after pad)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


# public names of the block (de)quantizer — the compressed_reduce strategy
# builds its wire format out of exactly these primitives
quantize = _quantize
dequantize = _dequantize


def topk_count(n: int, frac: float) -> int:
    """k for a top-`frac` selection out of `n` slots: ceil(frac * n),
    clamped to [1, n]. Shared by the topk_reduce strategy's reduce path and
    its `bytes_per_device` wire model so the two can never disagree."""
    return int(min(n, max(1, math.ceil(frac * n))))


def topk_select(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k selection along the last axis: `(indices, mask)` of the k
    largest entries per row (ties broken by position, exactly
    `jax.lax.top_k`'s order). `x` is the selection key — pass magnitudes,
    with invalid slots already pushed below every valid one. One top_k +
    one O(rows * k) scatter; no (rows, k, n) intermediate. The
    `topk_reduce` strategy gathers its wire payload with `indices` and
    updates its error-feedback residual with `mask`, so send and residual
    can never disagree about what was selected."""
    n = x.shape[-1]
    flat = x.reshape(-1, n)
    idx = jax.lax.top_k(flat, k)[1]                    # (rows, k)
    rows = jnp.arange(flat.shape[0])[:, None]
    mask = jnp.zeros(flat.shape, jnp.bool_).at[rows, idx].set(True)
    return (idx.reshape(x.shape[:-1] + (k,)), mask.reshape(x.shape))


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """The boolean-mask half of `topk_select` (exactly k True per row)."""
    return topk_select(x, k)[1]


def compress_psum(g: jax.Array, err: jax.Array, axis: str
                  ) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 psum over `axis`. g, err: same shape.

    Returns (mean-reduced g_hat, new error state).
    """
    shape = g.shape
    n = g.size
    pad = (-n) % BLOCK
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32) +
                   err.reshape(-1).astype(jnp.float32), (0, pad))
    q, scale = _quantize(flat)
    local_deq = _dequantize(q, scale, n)
    new_err = (flat[:n] - local_deq).reshape(shape)
    # put int8 on the wire: all_gather(q) + all_gather(scale), dequantize and
    # sum locally — for small pod counts this moves ~4x fewer bytes across
    # DCN than an f32 ring all-reduce
    q_all = jax.lax.all_gather(q, axis)               # (pods, blocks, BLOCK)
    s_all = jax.lax.all_gather(scale, axis)           # (pods, blocks, 1)
    deq = (q_all.astype(jnp.float32) * s_all).sum(0).reshape(-1)[:n]
    npods = compat.axis_size(axis)
    return deq.reshape(shape) / npods, new_err


def compress_tree_psum(grads, err_tree, axis: str):
    """Apply compress_psum leaf-wise."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs = [compress_psum(g, e, axis) for g, e in zip(flat_g, flat_e, strict=True)]
    g_hat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return g_hat, new_err


def init_error_state(params):
    """Zero error-feedback buffers, sharded like params."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes(params) -> tuple[int, int]:
    """(uncompressed, compressed) bytes per cross-pod reduction."""
    n = sum(p.size for p in jax.tree.leaves(params))
    raw = n * 4
    comp = n * 1 + (n // BLOCK + 1) * 4
    return raw, comp
