"""Sharded optimizers (functional, optax-free — offline container).

Optimizer moments live in the SAME sharding as their parameter (the DPMR
rule: state is co-located with the parameter's owner shard; updateParameters
never moves data). Moment dtype comes from ModelConfig.opt_dtype so very
large archs (llama3-405b, mixtral) can run bf16 moments to fit HBM.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.sharding import Annotated


class Optimizer(NamedTuple):
    init_defs: Callable      # (param_defs, opt_dtype) -> state defs tree
    init: Callable           # (params, opt_dtype) -> state tree
    update: Callable         # (grads, state, params, lr, cfg) -> (new_params, new_state)


def _zeros_like_defs(param_defs, opt_dtype):
    return jax.tree.map(
        lambda a: Annotated(a.shape, opt_dtype, a.logical), param_defs,
        is_leaf=lambda x: isinstance(x, Annotated))


def _zeros_like(params, opt_dtype):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, opt_dtype), params)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# --- SGD / momentum ---------------------------------------------------------


def _sgd_update(grads, state, params, lr, cfg: TrainConfig):
    new = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                     - lr * g.astype(jnp.float32)
                                     ).astype(p.dtype), params, grads)
    return new, state


def _momentum_init_defs(pd, od):
    return {"mu": _zeros_like_defs(pd, od)}


def _momentum_update(grads, state, params, lr, cfg: TrainConfig):
    mu = jax.tree.map(
        lambda m, g: (cfg.beta1 * m.astype(jnp.float32)
                      + g.astype(jnp.float32)).astype(m.dtype),
        state["mu"], grads)
    new = jax.tree.map(lambda p, m: (p.astype(jnp.float32)
                                     - lr * m.astype(jnp.float32)
                                     ).astype(p.dtype), params, mu)
    return new, {"mu": mu}


# --- Adam / AdamW -----------------------------------------------------------


def _adam_init_defs(pd, od):
    return {"m": _zeros_like_defs(pd, od), "v": _zeros_like_defs(pd, od),
            "count": Annotated((), "int32", ())}


def _adam_init(params, od):
    return {"m": _zeros_like(params, od), "v": _zeros_like(params, od),
            "count": jnp.zeros((), jnp.int32)}


def _adamw_update(grads, state, params, lr, cfg: TrainConfig,
                  weight_decay: float | None = None):
    wd = cfg.weight_decay if weight_decay is None else weight_decay
    count = state["count"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def moments(g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        return m32, v32

    def upd_p(p, g, m, v):
        m32, v32 = moments(g, m, v)
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + 1e-8)
        p32 = p.astype(jnp.float32)
        if wd:
            step = step + wd * p32
        return (p32 - lr * step).astype(p.dtype)

    # separate maps (params trees may contain tuples as structure, so we
    # cannot smuggle (p, m, v) tuples through as leaves); XLA CSEs the
    # recomputed moments inside jit.
    new_p = jax.tree.map(upd_p, params, grads, state["m"], state["v"])
    new_m = jax.tree.map(lambda g, m, v: moments(g, m, v)[0].astype(m.dtype),
                         grads, state["m"], state["v"])
    new_v = jax.tree.map(lambda g, m, v: moments(g, m, v)[1].astype(v.dtype),
                         grads, state["m"], state["v"])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def _adam_update(grads, state, params, lr, cfg):
    return _adamw_update(grads, state, params, lr, cfg, weight_decay=0.0)


OPTIMIZERS = {
    "sgd": Optimizer(lambda pd, od: {}, lambda p, od: {}, _sgd_update),
    "momentum": Optimizer(_momentum_init_defs,
                          lambda p, od: {"mu": _zeros_like(p, od)},
                          _momentum_update),
    "adam": Optimizer(_adam_init_defs, _adam_init, _adam_update),
    "adamw": Optimizer(_adam_init_defs, _adam_init, _adamw_update),
}


def get_optimizer(name: str) -> Optimizer:
    return OPTIMIZERS[name]


# --- DPMR sparse-face optimizers --------------------------------------------
#
# The sparse engine (core/dpmr.py, Algorithm 7 step 12) carries exactly ONE
# auxiliary array per parameter table (DPMRState.cold_acc / hot_acc), sharded
# like the parameter itself — the DPMR co-location rule. Sparse optimizers
# are therefore (theta, acc, grad, lr, cfg) -> (theta, acc) updates whose
# whole state fits that slot. They are selected by DPMRConfig.optimizer
# through the same named-registry pattern as the dense OPTIMIZERS table.


class SparseOptimizer(NamedTuple):
    update: Callable     # (theta, acc, grad, lr, cfg) -> (theta, acc)


def _sparse_sgd(theta, acc, grad, lr, cfg):
    return theta - lr * grad, acc


def _sparse_adagrad(theta, acc, grad, lr, cfg):
    acc = acc + grad * grad
    step = grad * jax.lax.rsqrt(acc + cfg.adagrad_eps)
    return theta - lr * step, acc


def _sparse_momentum(theta, acc, grad, lr, cfg):
    mu = cfg.momentum * acc + grad
    return theta - lr * mu, mu


SPARSE_OPTIMIZERS = {
    "sgd": SparseOptimizer(_sparse_sgd),
    "adagrad": SparseOptimizer(_sparse_adagrad),
    "momentum": SparseOptimizer(_sparse_momentum),
}


def register_sparse_optimizer(name: str, update: Callable):
    SPARSE_OPTIMIZERS[name] = SparseOptimizer(update)


def get_sparse_optimizer(name: str) -> SparseOptimizer:
    try:
        return SPARSE_OPTIMIZERS[name]
    except KeyError:
        raise KeyError(
            f"unknown sparse optimizer {name!r}; "
            f"registered: {sorted(SPARSE_OPTIMIZERS)}") from None
