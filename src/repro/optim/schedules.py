"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def get_schedule(cfg):
    if cfg.warmup_steps:
        return warmup_cosine(cfg.learning_rate, cfg.warmup_steps,
                             cfg.total_steps)
    return constant(cfg.learning_rate)


def _warmup_cosine_checked(lr, warmup_steps=0, total_steps=0):
    if total_steps <= warmup_steps:
        raise ValueError(
            f"warmup_cosine needs total_steps > warmup_steps, got "
            f"total_steps={total_steps}, warmup_steps={warmup_steps}")
    return warmup_cosine(lr, warmup_steps, total_steps)


# Named registry (shared by the dense trainer and the DPMR sparse face).
SCHEDULES = {
    "constant": lambda lr, warmup_steps=0, total_steps=0: constant(lr),
    "warmup_cosine": _warmup_cosine_checked,
}


def register_schedule(name: str, factory):
    """factory: (lr, warmup_steps=..., total_steps=...) -> (step -> lr)."""
    SCHEDULES[name] = factory


def get_schedule_by_name(name: str, lr: float, *, warmup_steps: int = 0,
                         total_steps: int = 0):
    try:
        factory = SCHEDULES[name]
    except KeyError:
        raise KeyError(f"unknown schedule {name!r}; "
                       f"registered: {sorted(SCHEDULES)}") from None
    return factory(lr, warmup_steps=warmup_steps, total_steps=total_steps)
