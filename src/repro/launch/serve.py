"""Serving drivers: dense LM decode, and the DPMR sparse serving engine.

Two modes behind one CLI:

  dense (default)   the original path: prefill + greedy decode of a model-
                    zoo architecture (`--arch`) on the host mesh.
  --sparse          the paper's face: a `repro.serve.DPMRServeEngine` keeps
                    the sharded parameter state resident on the mesh
                    (restored from a sparse checkpoint via `--ckpt`, or
                    optionally warm-trained in place with `--warm-steps`),
                    and `--clients` threads stream `file_sparse` /
                    `zipf_sparse`-shaped requests through the deadline-
                    coalesced micro-batcher + hot-feature cache. Prints
                    p50/p99 latency, sustained QPS, and the cache/batching
                    counters.

The modes are mutually exclusive and fail loudly when mixed: `--arch`
names a dense LM config and is rejected under `--sparse`, and `--sparse`
refuses a checkpoint directory whose manifest is not `kind=dpmr_sparse`.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b
  PYTHONPATH=src python -m repro.launch.train --sparse --steps 40 \
      --ckpt /tmp/ck                       # produce a sparse checkpoint
  PYTHONPATH=src python -m repro.launch.serve --sparse --ckpt /tmp/ck \
      --requests 256 --max-wait-ms 2
"""
from __future__ import annotations

import argparse
import logging
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.train import serve, trainer

log = logging.getLogger("repro.serve")


def serve_sparse(args) -> dict:
    """Drive the sparse serving engine; returns the metrics snapshot."""
    from repro.api import DPMREngine
    from repro.configs.base import DPMRConfig
    from repro.data import get_source
    from repro.serve import BatchingConfig, DPMRServeEngine, HotCacheConfig

    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    if args.data_dir:
        source = get_source("file_sparse", directory=args.data_dir)
    else:
        source = get_source("zipf_sparse", batch_size=args.request_size,
                            num_batches=max(args.requests, 1),
                            num_features=args.features,
                            features_per_sample=16, seed=args.data_seed)
    probe = source.batch(0)
    k = int(probe["ids"].shape[1])
    cfg = DPMRConfig(num_features=args.features, max_features_per_sample=k,
                     distribution=args.strategy)

    batching = BatchingConfig(max_batch=args.max_batch,
                              max_wait_ms=args.max_wait_ms)
    hot = HotCacheConfig(max_hot=args.hot_max, threshold=args.hot_threshold,
                         window=args.hot_window,
                         refresh_every=args.hot_refresh_every) \
        if args.hot_cache else None

    if args.ckpt:
        srv = DPMRServeEngine.from_checkpoint(cfg, mesh, args.ckpt,
                                              batching=batching,
                                              hot_cache=hot)
        log.info("restored sparse state at step %d from %s",
                 int(srv.engine.state.step), args.ckpt)
    else:
        engine = DPMREngine(cfg, mesh)
        if args.warm_steps:
            engine.fit_sgd(source.iter_batches(), steps=args.warm_steps)
            log.info("warm-trained %d steps (no --ckpt given)",
                     args.warm_steps)
        else:
            log.warning("serving ZERO parameters (no --ckpt, no "
                        "--warm-steps): every probability is 0.5")
        srv = DPMRServeEngine(engine, batching=batching, hot_cache=hot)

    n = args.requests
    if source.num_batches is not None:
        n = min(n, source.num_batches)
    requests = [source.batch(i) for i in range(n)]
    results: list = [None] * n
    srv.metrics.reset_clock()
    t0 = time.time()

    def client(lo: int, hi: int) -> None:
        for i in range(lo, hi):
            results[i] = srv.submit(requests[i]["ids"],
                                    requests[i]["vals"])

    clients = max(1, args.clients)
    per = -(-n // clients)
    threads = [threading.Thread(target=client,
                                args=(c * per, min(n, (c + 1) * per)))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    probs = [np.asarray(f.result(timeout=120)) for f in results]
    wall = time.time() - t0
    srv.stop()

    m = srv.metrics_snapshot()
    print(f"[sparse] {n} requests x {requests[0]['ids'].shape[0]} samples "
          f"from {clients} clients in {wall:.2f}s "
          f"({n / max(wall, 1e-9):.1f} req/s)")
    print(f"  latency p50 {m.get('latency_p50_ms', float('nan')):.2f}ms "
          f"p99 {m.get('latency_p99_ms', float('nan')):.2f}ms; "
          f"flushes {m.get('flushes', 0)} "
          f"(full {m.get('flush_full', 0)} / deadline "
          f"{m.get('flush_deadline', 0)}); "
          f"compiled step fns {m['compiled_step_fns']}")
    if args.hot_cache:
        print(f"  hot cache: hit rate {m.get('hot_hit_rate', 0.0):.3f} "
              f"({m.get('cache_hits', 0)} hits / "
              f"{m.get('cache_misses', 0)} misses), "
              f"refreshes {m.get('cache_refreshes', 0)} "
              f"(stale {m.get('cache_stale_refreshes', 0)})")
    print(f"  first request -> {probs[0][:4]}")
    return m


def serve_dense(args) -> None:
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = registry.smoke_config(args.arch) if args.smoke else \
        registry.get_spec(args.arch).cfg
    spec = registry.get_spec(args.arch)
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, TrainConfig(optimizer="sgd"),
                                   ParallelConfig(), jax.random.PRNGKey(0))
        params = state["params"]
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
            jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.normal(size=(
                args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
        t0 = time.time()
        toks = serve.greedy_decode(spec, cfg, params, batch,
                                   args.decode_steps,
                                   ParallelConfig(seq_shard=False))
        dt = time.time() - t0
    print(f"decoded {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.decode_steps / dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="model zoo id (dense mode; rejected "
                                   "under --sparse)")
    # BooleanOptionalAction so --no-smoke can actually select the full
    # config (store_true with default=True could never be disabled)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced same-family config (--no-smoke = full)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    # sparse serving mode
    ap.add_argument("--sparse", action="store_true",
                    help="serve the DPMR sparse face through "
                         "repro.serve.DPMRServeEngine")
    ap.add_argument("--ckpt", default="",
                    help="sparse: restore this sparse checkpoint "
                         "(manifest kind must be dpmr_sparse)")
    ap.add_argument("--features", type=int, default=1 << 14,
                    help="sparse: hashed feature-space size")
    ap.add_argument("--strategy", default="a2a",
                    help="sparse: distribution strategy name")
    ap.add_argument("--data-dir", default="",
                    help="sparse: serve requests shaped from a file_sparse "
                         "corpus instead of the synthetic zipf stream")
    ap.add_argument("--requests", type=int, default=128,
                    help="sparse: number of requests to drive")
    ap.add_argument("--request-size", type=int, default=4,
                    help="sparse: samples per request (zipf source)")
    ap.add_argument("--clients", type=int, default=8,
                    help="sparse: concurrent client threads")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="sparse: coalescer flush size (rows)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="sparse: coalescer deadline window")
    ap.add_argument("--hot-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="sparse: host-side Zipf-head parameter cache")
    ap.add_argument("--hot-max", type=int, default=256,
                    help="sparse: hot-cache slots")
    ap.add_argument("--hot-threshold", type=float, default=0.001,
                    help="sparse: min in-window frequency to cache")
    ap.add_argument("--hot-window", type=int, default=512,
                    help="sparse: sliding request window size")
    ap.add_argument("--hot-refresh-every", type=int, default=256,
                    help="sparse: staleness bound (lookups per mirror)")
    ap.add_argument("--warm-steps", type=int, default=0,
                    help="sparse: train this many steps in place when no "
                         "--ckpt is given (demo-quality parameters)")
    ap.add_argument("--data-seed", type=int, default=0)
    return ap


def main():
    logging.basicConfig(level=logging.INFO)
    ap = build_parser()
    args = ap.parse_args()
    if args.sparse:
        if args.arch:
            # fail loudly instead of silently ignoring a dense config: the
            # two modes serve different state and share no flags
            ap.error(f"--arch {args.arch!r} is a dense LM config; the "
                     "sparse mode serves a DPMR checkpoint (--ckpt) — "
                     "pass exactly one of --arch / --sparse")
        serve_sparse(args)
        return
    if not args.arch:
        ap.error("--arch is required (or pass --sparse)")
    serve_dense(args)


if __name__ == "__main__":
    main()
