"""Batched serving driver: prefill + greedy decode on the host mesh."""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.train import serve, trainer

log = logging.getLogger("repro.serve")


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    # BooleanOptionalAction so --no-smoke can actually select the full
    # config (store_true with default=True could never be disabled)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced same-family config (--no-smoke = full)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = registry.smoke_config(args.arch) if args.smoke else \
        registry.get_spec(args.arch).cfg
    spec = registry.get_spec(args.arch)
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, TrainConfig(optimizer="sgd"),
                                   ParallelConfig(), jax.random.PRNGKey(0))
        params = state["params"]
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
            jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.normal(size=(
                args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
        t0 = time.time()
        toks = serve.greedy_decode(spec, cfg, params, batch,
                                   args.decode_steps,
                                   ParallelConfig(seq_shard=False))
        dt = time.time() - t0
    print(f"decoded {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.decode_steps / dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
