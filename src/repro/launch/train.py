"""End-to-end training driver (host-scale; full configs go through dryrun).

Wires together: model zoo, DPMR-dense sharded trainer, the `repro.data`
plane (lm_markov source + prefetching ShardedLoader with a resumable
cursor), checkpoint manager (atomic/keep-N/async), preemption guard,
straggler watchdog, and resume (model + optimizer + exact data position).

`--sparse` drives the paper's sparse face instead (DPMREngine over a
zipf_sparse loader); `--strategy` selects any registered distribution
strategy (a2a | allgather | psum_scatter | hier_a2a | compressed_reduce |
topk_reduce | overlap_a2a | user-registered) and engine save()/restore()
carries the model, the strategy carry (compression error feedback /
top-k sparsification residual), and the data cursor.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --sparse \
      --strategy compressed_reduce --steps 40 --batch 512 --ckpt /tmp/ck
  # kill either mid-run; rerun the same command: it resumes from the ckpt
"""
from __future__ import annotations

import argparse
import hashlib
import json
import logging

import jax
import numpy as np

from repro import compat
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import Cursor, ShardedLoader, get_source
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerWatchdog
from repro.train import trainer

log = logging.getLogger("repro.train")


def make_loader(args, cfg, mesh=None) -> ShardedLoader:
    """The driver's data plane: lm_markov source (with encoder frames for
    encdec families) behind a prefetching loader. Batches stay host-shaped
    ("device" placement) — the jitted trainer step owns distribution.
    Pinned to a single stream (host 0 of 1): every process must feed the
    jitted step identical global batches, exactly as the pre-loader driver
    did; per-host disjoint shards need global-array placement first."""
    source = get_source(
        "lm_markov", vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch, seed=args.data_seed,
        encdec_d_model=cfg.d_model if cfg.family == "encdec" else 0)
    return ShardedLoader(source, mesh, placement="device",
                         host_index=0, num_hosts=1,
                         prefetch=args.prefetch)


def sparse_loop(args) -> dict:
    """Sparse-face driver: DPMREngine + zipf_sparse loader (or, with
    --data-dir, a file_sparse corpus under chunk-aligned shard ownership),
    strategy by name (--strategy), resumable via engine save()/restore()
    (state incl. the strategy carry + the loader cursor).

    Three execution modes over one loop (docs/DISTRIBUTED.md):
      * --hosts H --host-id h: single-process EMULATION of host h — the
        loader serves only that host's shard (its owned chunk range for
        file corpora, its batch stride otherwise);
      * --hosts H --host-id -1: all-hosts emulation — one process serves
        the concatenated H*B-row global batch every step, the parity
        baseline a real H-process run must bit-match;
      * --coordinator/--num-processes/--process-id (one invocation per
        process): REAL `jax.distributed` execution — process h is host h,
        its loader materializes only host h's batches, and the placement
        seam assembles them into global arrays
        (`runtime/multiprocess.global_batch_placement`)."""
    from repro.api import DPMREngine, ShardedLoader, get_source, get_strategy
    from repro.ckpt.checkpointer import Checkpointer as Ck
    from repro.configs.base import DPMRConfig
    from repro.runtime import multiprocess as mp

    ctx = mp.context()
    hosts, host_id = args.hosts, args.host_id
    if ctx.is_distributed:
        if hosts not in (1, ctx.num_processes) or host_id == -1:
            raise SystemExit(
                "real multi-process runs derive the data plane from the "
                "process topology: drop --hosts/--host-id (process h IS "
                "host h of --num-processes)")
        hosts, host_id = ctx.num_processes, ctx.process_id
    get_strategy(args.strategy)          # fail fast on unknown names
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = DPMRConfig(num_features=args.features,
                     max_features_per_sample=32,
                     distribution=args.strategy, optimizer="adagrad",
                     learning_rate=args.lr)
    if args.data_dir:
        source = get_source("file_sparse", directory=args.data_dir)
    else:
        source = get_source("zipf_sparse", batch_size=args.batch,
                            num_batches=args.sparse_batches,
                            num_features=args.features,
                            features_per_sample=32, seed=args.data_seed)
    eval_source = source         # deterministic final eval reads raw batches
    if host_id == -1:
        # parity baseline: one process, every host's stream, concatenated
        source = mp.emulate_all_hosts(source, hosts)
        hosts, host_id = 1, 0
    loader = ShardedLoader(
        source, mesh, host_index=host_id, num_hosts=hosts,
        prefetch=args.prefetch, shuffle=args.shuffle,
        placement=mp.global_batch_placement(mesh) if ctx.is_distributed
        else "sharded")
    if loader.assignment is not None and loader.assignment.kind == "chunk":
        log.info("chunk ownership: host %d/%d owns chunks [%d, %d) of %d",
                 host_id, hosts,
                 loader.assignment.owned_chunks(host_id).start,
                 loader.assignment.owned_chunks(host_id).stop,
                 loader.assignment.num_chunks)
    engine = DPMREngine(cfg, mesh)
    if args.ckpt and Ck(args.ckpt).latest_step() is not None:
        # reassign rather than refuse when --hosts changed between runs:
        # the loop resumes at the epoch boundary under the new ownership
        engine.restore(args.ckpt, loader=loader, on_host_change="reassign")
        log.info("resumed sparse run at step %d (strategy %s)",
                 int(engine.state.step), args.strategy)
    # checkpoint every --save-every steps (like the dense loop), so a
    # killed run resumes mid-stream instead of restarting from step 0.
    # --async-ckpt keeps only the device->host snapshot on the step path;
    # the final save is always blocking (flushes any in-flight write)
    history = []
    while int(engine.state.step) < args.steps:
        chunk = min(args.save_every, args.steps - int(engine.state.step))
        history += engine.fit_sgd(loader, steps=chunk)
        if args.ckpt:
            engine.save(args.ckpt, keep=args.keep,
                        block=not args.async_ckpt)
    if args.ckpt and args.async_ckpt:
        engine.save(args.ckpt, keep=args.keep)      # blocking flush
    try:
        # the most recently used compilation — the CONFORMED global batch
        # size fit_sgd actually trained on (the raw source batch size may
        # not divide the mesh and would fail make_step_fns' divisibility
        # assert)
        fns = engine.fns
    except RuntimeError:
        # nothing trained this run (restored at/after --steps): compile at
        # the size the loader would serve
        bs = int(getattr(loader.source, "batch_size", 0)) or args.batch
        fns = engine.step_fns(bs - bs % loader.batch_divisor or bs)
    wire = get_strategy(args.strategy).bytes_per_device(fns.ctx)
    # deterministic parity probe: the pmean loss METRIC can wobble ~1 ulp
    # across process boundaries (reduction order), so cross-mode parity is
    # asserted on the final parameters (digest) and on a loss recomputed
    # host-side in float64 over a fixed raw batch — bit-identical exactly
    # when the parameters are (scripts/check_multiprocess.py)
    eval_batch = eval_source.batch(0)
    probs = np.asarray(engine.predict({"ids": eval_batch["ids"],
                                       "vals": eval_batch["vals"]}),
                       np.float64)
    y = np.asarray(eval_batch["labels"], np.float64)
    eps = 1e-9
    final_eval = float(-np.mean(y * np.log(probs + eps)
                                + (1 - y) * np.log(1 - probs + eps)))
    digest = hashlib.md5(
        mp.host_value(engine.state.cold).tobytes()).hexdigest()
    return {"history": history, "last_step": int(engine.state.step),
            "strategy": args.strategy,
            "wire_bytes": {"inner": wire.inner, "outer": wire.outer},
            "losses": [h["loss"] for h in history],
            "final_eval_loss": final_eval, "cold_md5": digest,
            "num_processes": ctx.num_processes,
            "process_id": ctx.process_id, "hosts": hosts}


def train_loop(args, fail_injector=None) -> dict:
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = registry.smoke_config(args.arch) if args.smoke else \
        registry.get_spec(args.arch).cfg
    spec = registry.get_spec(args.arch)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=args.warmup,
                     total_steps=args.steps, optimizer=args.optimizer)
    pc = ParallelConfig(microbatches=args.microbatches)
    loader = make_loader(args, cfg, mesh)
    ck = Checkpointer(args.ckpt, keep=args.keep) if args.ckpt else None
    guard = PreemptionGuard() if args.preemption_guard else None
    watchdog = StragglerWatchdog()

    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc,
                                   jax.random.PRNGKey(tc.seed))
        start_step = 0
        if ck is not None and ck.latest_step() is not None:
            state, manifest = ck.restore(state)
            extra = manifest["extra"]
            if "data" in extra:                      # cursor-carrying ckpt
                loader.load_state_dict(extra["data"])
                start_step = loader.cursor.step
            else:                                    # pre-data-plane ckpt
                start_step = extra["data_step"]
                loader.seek(Cursor(0, start_step))
            log.info("resumed from step %d", start_step)
        step_fn = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))

        def save(step, block):
            ck.save(step, state,
                    extra={"data_step": step, "data": loader.state_dict()},
                    block=block)

        losses = []
        i = start_step
        for batch in loader.batches(args.steps - start_step):
            watchdog.step_start()
            if fail_injector is not None:
                fail_injector.maybe_fail(i)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            watchdog.step_end(i)
            i += 1
            if args.log_every and i % args.log_every == 0:
                log.info("step %d loss %.4f lr %.2e", i, loss,
                         float(metrics["lr"]))
            if ck is not None and (i % args.save_every == 0
                                   or i == args.steps):
                save(i, block=not args.async_ckpt)
            if guard is not None and guard.preempted():
                if ck is not None:
                    save(i, block=True)
                log.warning("preempted; saved at step %d", i)
                break
        if ck is not None:
            ck.wait()
    return {"state": state, "losses": losses, "last_step": i,
            "straggler_events": watchdog.events}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="model zoo id (dense face; required "
                                   "unless --sparse)")
    ap.add_argument("--sparse", action="store_true",
                    help="train the DPMR sparse face (DPMREngine over a "
                         "zipf_sparse loader) instead of a zoo model")
    ap.add_argument("--strategy", default="a2a",
                    help="sparse-face distribution strategy (any name in "
                         "repro.api.list_strategies())")
    ap.add_argument("--features", type=int, default=1 << 14,
                    help="sparse-face hashed feature-space size")
    ap.add_argument("--sparse-batches", type=int, default=64,
                    help="sparse-face corpus size in batches (one epoch)")
    ap.add_argument("--data-dir", default="",
                    help="sparse face: read a file_sparse corpus (written "
                         "by write_file_corpus) from this directory under "
                         "chunk-aligned shard ownership instead of the "
                         "synthetic zipf_sparse stream")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulate a data plane divided over this many "
                         "hosts (this process serves one of them)")
    ap.add_argument("--host-id", type=int, default=0,
                    help="which host of --hosts this process simulates; "
                         "-1 emulates ALL hosts in one process (the "
                         "concatenated global batch — the parity baseline "
                         "for a real --num-processes run)")
    ap.add_argument("--coordinator", default="",
                    help="jax.distributed coordinator address host:port "
                         "(process 0 serves it); required with "
                         "--num-processes > 1")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="total processes in a REAL multi-process run "
                         "(one launch/train.py invocation per process; "
                         "sparse face only — see docs/DISTRIBUTED.md)")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's rank in [0, --num-processes)")
    ap.add_argument("--local-devices", type=int, default=0,
                    help="force this process's emulated CPU device count "
                         "(XLA_FLAGS host-platform trick; 0 = leave the "
                         "environment alone). Global mesh devices = "
                         "--local-devices x --num-processes")
    ap.add_argument("--json", action="store_true",
                    help="print the run summary as one JSON line (losses, "
                         "final_eval_loss, cold_md5) — what the parity "
                         "checkers consume")
    ap.add_argument("--shuffle", action="store_true",
                    help="per-epoch loader shuffling (seeded, resume-exact)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=2,
                    help="loader prefetch depth (0 = synchronous input)")
    ap.add_argument("--log-every", type=int, default=10)
    # BooleanOptionalAction so --no-preemption-guard is expressible
    # (store_true with default=True could never be disabled)
    ap.add_argument("--preemption-guard",
                    action=argparse.BooleanOptionalAction, default=True)
    return ap


def main():
    logging.basicConfig(level=logging.INFO)
    args = build_parser().parse_args()
    if args.num_processes > 1 or args.local_devices:
        # must run before the first jax computation (backend init reads
        # XLA_FLAGS once; jax.distributed must precede any collective)
        from repro.runtime import multiprocess

        multiprocess.initialize(
            coordinator=args.coordinator,
            num_processes=args.num_processes, process_id=args.process_id,
            local_device_count=args.local_devices or None)
    if args.sparse:
        out = sparse_loop(args)
        wb = out["wire_bytes"]
        print(f"[{out['strategy']}] final loss "
              f"{out['losses'][-1] if out['losses'] else float('nan'):.4f} "
              f"after {out['last_step']} steps; wire bytes/device/step "
              f"inner={wb['inner']} outer={wb['outer']}")
        if args.json:
            out.pop("history", None)
            print(json.dumps(out))
        return
    if args.num_processes > 1:
        raise SystemExit("--num-processes applies to the sparse face "
                         "(--sparse); the dense driver is single-process")
    if not args.arch:
        raise SystemExit("--arch is required (or pass --sparse)")
    out = train_loop(args)
    print(f"final loss {out['losses'][-1]:.4f} after {out['last_step']} steps")


if __name__ == "__main__":
    main()
