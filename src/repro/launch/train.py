"""End-to-end training driver (host-scale; full configs go through dryrun).

Wires together: model zoo, DPMR-dense sharded trainer, the `repro.data`
plane (lm_markov source + prefetching ShardedLoader with a resumable
cursor), checkpoint manager (atomic/keep-N/async), preemption guard,
straggler watchdog, and resume (model + optimizer + exact data position).

`--sparse` drives the paper's sparse face instead (DPMREngine over a
zipf_sparse loader); `--strategy` selects any registered distribution
strategy (a2a | allgather | psum_scatter | hier_a2a | compressed_reduce |
topk_reduce | overlap_a2a | user-registered) and engine save()/restore()
carries the model, the strategy carry (compression error feedback /
top-k sparsification residual), and the data cursor.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --sparse \
      --strategy compressed_reduce --steps 40 --batch 512 --ckpt /tmp/ck
  # kill either mid-run; rerun the same command: it resumes from the ckpt
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro import compat
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import Cursor, ShardedLoader, get_source
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerWatchdog
from repro.train import trainer

log = logging.getLogger("repro.train")


def make_loader(args, cfg, mesh=None) -> ShardedLoader:
    """The driver's data plane: lm_markov source (with encoder frames for
    encdec families) behind a prefetching loader. Batches stay host-shaped
    ("device" placement) — the jitted trainer step owns distribution.
    Pinned to a single stream (host 0 of 1): every process must feed the
    jitted step identical global batches, exactly as the pre-loader driver
    did; per-host disjoint shards need global-array placement first."""
    source = get_source(
        "lm_markov", vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch, seed=args.data_seed,
        encdec_d_model=cfg.d_model if cfg.family == "encdec" else 0)
    return ShardedLoader(source, mesh, placement="device",
                         host_index=0, num_hosts=1,
                         prefetch=args.prefetch)


def sparse_loop(args) -> dict:
    """Sparse-face driver: DPMREngine + zipf_sparse loader (or, with
    --data-dir, a file_sparse corpus under chunk-aligned shard ownership),
    strategy by name (--strategy), resumable via engine save()/restore()
    (state incl. the strategy carry + the loader cursor).

    --hosts/--host-id simulate one host of a multi-process data plane in
    a single process: the loader serves ONLY this host's shard (its owned
    chunk range for file corpora, its batch stride otherwise). A real
    multi-host deployment runs one such process per host."""
    from repro.api import DPMREngine, ShardedLoader, get_source, get_strategy
    from repro.ckpt.checkpointer import Checkpointer as Ck
    from repro.configs.base import DPMRConfig

    get_strategy(args.strategy)          # fail fast on unknown names
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = DPMRConfig(num_features=args.features,
                     max_features_per_sample=32,
                     distribution=args.strategy, optimizer="adagrad",
                     learning_rate=args.lr)
    if args.data_dir:
        source = get_source("file_sparse", directory=args.data_dir)
    else:
        source = get_source("zipf_sparse", batch_size=args.batch,
                            num_batches=args.sparse_batches,
                            num_features=args.features,
                            features_per_sample=32, seed=args.data_seed)
    loader = ShardedLoader(
        source, mesh, host_index=args.host_id, num_hosts=args.hosts,
        prefetch=args.prefetch, shuffle=args.shuffle)
    if loader.assignment is not None:
        log.info("chunk ownership: host %d/%d owns chunks [%d, %d) of %d",
                 args.host_id, args.hosts,
                 loader.assignment.owned_chunks(args.host_id).start,
                 loader.assignment.owned_chunks(args.host_id).stop,
                 loader.assignment.num_chunks)
    engine = DPMREngine(cfg, mesh)
    if args.ckpt and Ck(args.ckpt).latest_step() is not None:
        # reassign rather than refuse when --hosts changed between runs:
        # the loop resumes at the epoch boundary under the new ownership
        engine.restore(args.ckpt, loader=loader, on_host_change="reassign")
        log.info("resumed sparse run at step %d (strategy %s)",
                 int(engine.state.step), args.strategy)
    # checkpoint every --save-every steps (like the dense loop), so a
    # killed run resumes mid-stream instead of restarting from step 0
    history = []
    while int(engine.state.step) < args.steps:
        chunk = min(args.save_every, args.steps - int(engine.state.step))
        history += engine.fit_sgd(loader, steps=chunk)
        if args.ckpt:
            engine.save(args.ckpt, keep=args.keep)
    try:
        # the most recently used compilation — the CONFORMED global batch
        # size fit_sgd actually trained on (the raw source batch size may
        # not divide the mesh and would fail make_step_fns' divisibility
        # assert)
        fns = engine.fns
    except RuntimeError:
        # nothing trained this run (restored at/after --steps): compile at
        # the size the loader would serve
        bs = int(getattr(loader.source, "batch_size", 0)) or args.batch
        fns = engine.step_fns(bs - bs % loader.batch_divisor or bs)
    wire = get_strategy(args.strategy).bytes_per_device(fns.ctx)
    return {"history": history, "last_step": int(engine.state.step),
            "strategy": args.strategy,
            "wire_bytes": {"inner": wire.inner, "outer": wire.outer},
            "losses": [h["loss"] for h in history]}


def train_loop(args, fail_injector=None) -> dict:
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = registry.smoke_config(args.arch) if args.smoke else \
        registry.get_spec(args.arch).cfg
    spec = registry.get_spec(args.arch)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=args.warmup,
                     total_steps=args.steps, optimizer=args.optimizer)
    pc = ParallelConfig(microbatches=args.microbatches)
    loader = make_loader(args, cfg, mesh)
    ck = Checkpointer(args.ckpt, keep=args.keep) if args.ckpt else None
    guard = PreemptionGuard() if args.preemption_guard else None
    watchdog = StragglerWatchdog()

    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc,
                                   jax.random.PRNGKey(tc.seed))
        start_step = 0
        if ck is not None and ck.latest_step() is not None:
            state, manifest = ck.restore(state)
            extra = manifest["extra"]
            if "data" in extra:                      # cursor-carrying ckpt
                loader.load_state_dict(extra["data"])
                start_step = loader.cursor.step
            else:                                    # pre-data-plane ckpt
                start_step = extra["data_step"]
                loader.seek(Cursor(0, start_step))
            log.info("resumed from step %d", start_step)
        step_fn = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))

        def save(step, block):
            ck.save(step, state,
                    extra={"data_step": step, "data": loader.state_dict()},
                    block=block)

        losses = []
        i = start_step
        for batch in loader.batches(args.steps - start_step):
            watchdog.step_start()
            if fail_injector is not None:
                fail_injector.maybe_fail(i)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            watchdog.step_end(i)
            i += 1
            if args.log_every and i % args.log_every == 0:
                log.info("step %d loss %.4f lr %.2e", i, loss,
                         float(metrics["lr"]))
            if ck is not None and (i % args.save_every == 0
                                   or i == args.steps):
                save(i, block=not args.async_ckpt)
            if guard is not None and guard.preempted():
                if ck is not None:
                    save(i, block=True)
                log.warning("preempted; saved at step %d", i)
                break
        if ck is not None:
            ck.wait()
    return {"state": state, "losses": losses, "last_step": i,
            "straggler_events": watchdog.events}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="model zoo id (dense face; required "
                                   "unless --sparse)")
    ap.add_argument("--sparse", action="store_true",
                    help="train the DPMR sparse face (DPMREngine over a "
                         "zipf_sparse loader) instead of a zoo model")
    ap.add_argument("--strategy", default="a2a",
                    help="sparse-face distribution strategy (any name in "
                         "repro.api.list_strategies())")
    ap.add_argument("--features", type=int, default=1 << 14,
                    help="sparse-face hashed feature-space size")
    ap.add_argument("--sparse-batches", type=int, default=64,
                    help="sparse-face corpus size in batches (one epoch)")
    ap.add_argument("--data-dir", default="",
                    help="sparse face: read a file_sparse corpus (written "
                         "by write_file_corpus) from this directory under "
                         "chunk-aligned shard ownership instead of the "
                         "synthetic zipf_sparse stream")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulate a data plane divided over this many "
                         "hosts (this process serves one of them)")
    ap.add_argument("--host-id", type=int, default=0,
                    help="which host of --hosts this process simulates")
    ap.add_argument("--shuffle", action="store_true",
                    help="per-epoch loader shuffling (seeded, resume-exact)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=2,
                    help="loader prefetch depth (0 = synchronous input)")
    ap.add_argument("--log-every", type=int, default=10)
    # BooleanOptionalAction so --no-preemption-guard is expressible
    # (store_true with default=True could never be disabled)
    ap.add_argument("--preemption-guard",
                    action=argparse.BooleanOptionalAction, default=True)
    return ap


def main():
    logging.basicConfig(level=logging.INFO)
    args = build_parser().parse_args()
    if args.sparse:
        out = sparse_loop(args)
        wb = out["wire_bytes"]
        print(f"[{out['strategy']}] final loss "
              f"{out['losses'][-1] if out['losses'] else float('nan'):.4f} "
              f"after {out['last_step']} steps; wire bytes/device/step "
              f"inner={wb['inner']} outer={wb['outer']}")
        return
    if not args.arch:
        raise SystemExit("--arch is required (or pass --sparse)")
    out = train_loop(args)
    print(f"final loss {out['losses'][-1]:.4f} after {out['last_step']} steps")


if __name__ == "__main__":
    main()
