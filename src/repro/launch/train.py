"""End-to-end training driver (host-scale; full configs go through dryrun).

Wires together: model zoo, DPMR-dense sharded trainer, the `repro.data`
plane (lm_markov source + prefetching ShardedLoader with a resumable
cursor), checkpoint manager (atomic/keep-N/async), preemption guard,
straggler watchdog, and resume (model + optimizer + exact data position).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
  # kill it mid-run; rerun the same command: it resumes from the checkpoint
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro import compat
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import Cursor, ShardedLoader, get_source
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.runtime.fault_tolerance import (FailureInjector, PreemptionGuard,
                                           StragglerWatchdog)
from repro.train import trainer

log = logging.getLogger("repro.train")


def make_loader(args, cfg, mesh=None) -> ShardedLoader:
    """The driver's data plane: lm_markov source (with encoder frames for
    encdec families) behind a prefetching loader. Batches stay host-shaped
    ("device" placement) — the jitted trainer step owns distribution.
    Pinned to a single stream (host 0 of 1): every process must feed the
    jitted step identical global batches, exactly as the pre-loader driver
    did; per-host disjoint shards need global-array placement first."""
    source = get_source(
        "lm_markov", vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch, seed=args.data_seed,
        encdec_d_model=cfg.d_model if cfg.family == "encdec" else 0)
    return ShardedLoader(source, mesh, placement="device",
                         host_index=0, num_hosts=1,
                         prefetch=args.prefetch)


def train_loop(args, fail_injector=None) -> dict:
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    cfg = registry.smoke_config(args.arch) if args.smoke else \
        registry.get_spec(args.arch).cfg
    spec = registry.get_spec(args.arch)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=args.warmup,
                     total_steps=args.steps, optimizer=args.optimizer)
    pc = ParallelConfig(microbatches=args.microbatches)
    loader = make_loader(args, cfg, mesh)
    ck = Checkpointer(args.ckpt, keep=args.keep) if args.ckpt else None
    guard = PreemptionGuard() if args.preemption_guard else None
    watchdog = StragglerWatchdog()

    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc,
                                   jax.random.PRNGKey(tc.seed))
        start_step = 0
        if ck is not None and ck.latest_step() is not None:
            state, manifest = ck.restore(state)
            extra = manifest["extra"]
            if "data" in extra:                      # cursor-carrying ckpt
                loader.load_state_dict(extra["data"])
                start_step = loader.cursor.step
            else:                                    # pre-data-plane ckpt
                start_step = extra["data_step"]
                loader.seek(Cursor(0, start_step))
            log.info("resumed from step %d", start_step)
        step_fn = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))

        def save(step, block):
            ck.save(step, state,
                    extra={"data_step": step, "data": loader.state_dict()},
                    block=block)

        losses = []
        i = start_step
        for batch in loader.batches(args.steps - start_step):
            watchdog.step_start()
            if fail_injector is not None:
                fail_injector.maybe_fail(i)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            watchdog.step_end(i)
            i += 1
            if args.log_every and i % args.log_every == 0:
                log.info("step %d loss %.4f lr %.2e", i, loss,
                         float(metrics["lr"]))
            if ck is not None and (i % args.save_every == 0
                                   or i == args.steps):
                save(i, block=not args.async_ckpt)
            if guard is not None and guard.preempted():
                if ck is not None:
                    save(i, block=True)
                log.warning("preempted; saved at step %d", i)
                break
        if ck is not None:
            ck.wait()
    return {"state": state, "losses": losses, "last_step": i,
            "straggler_events": watchdog.events}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=2,
                    help="loader prefetch depth (0 = synchronous input)")
    ap.add_argument("--log-every", type=int, default=10)
    # BooleanOptionalAction so --no-preemption-guard is expressible
    # (store_true with default=True could never be disabled)
    ap.add_argument("--preemption-guard",
                    action=argparse.BooleanOptionalAction, default=True)
    return ap


def main():
    logging.basicConfig(level=logging.INFO)
    args = build_parser().parse_args()
    out = train_loop(args)
    print(f"final loss {out['losses'][-1]:.4f} after {out['last_step']} steps")


if __name__ == "__main__":
    main()
