import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and the production meshes need 512 placeholder host devices.
(Smoke tests and benchmarks never import this module, so they see 1 device.)

Per cell this prints/records:
  - compiled.memory_analysis()  (bytes per device: proves it fits)
  - compiled.cost_analysis()    (HLO FLOPs / bytes for the roofline terms)
  - the collective schedule (op kind, dtype, shape, participant count)
    parsed from the optimized HLO — cost_analysis has no collective bytes.

Usage:
  python -m repro.launch.dryrun --cell granite-8b:train_4k:single   # one cell
  python -m repro.launch.dryrun --all --out results/dryrun          # sweep
The sweep spawns one subprocess per cell (compile isolation + memory reclaim
on a 1-core host); each cell appends <out>/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import subprocess
import sys
import time


def _collectives_from_hlo(hlo: str):
    """Parse collective ops from optimized HLO text.

    Returns a list of {op, dtype, shape, elems, bytes, groups, group_size}.
    """
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    dsize = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
             "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
             "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    out = []
    # e.g.:  %ag = bf16[16,1024,512]{...} all-gather(...), replica_groups=...
    pat = re.compile(
        r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^a-z]*\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")
    gpat = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    gpat2 = re.compile(r"replica_groups=\{\{([^}]*)\}")
    for line in hlo.splitlines():
        if not any(o in line for o in ops):
            continue
        m = pat.search(line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if "-done" in line:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        gsize = None
        g = gpat.search(line)
        if g:
            gsize = int(g.group(2))
        else:
            g2 = gpat2.search(line)
            if g2:
                gsize = len(g2.group(1).split(","))
        out.append({
            "op": kind, "dtype": dt, "elems": elems,
            "bytes": elems * dsize.get(dt, 4), "group_size": gsize,
        })
    return out


def run_strategy_wire(global_batch: int = 1 << 24, k: int = 64,
                      feature_space: int = 1 << 30) -> list:
    """Two-tier wire report for every registered distribution strategy on
    the production mesh geometries (analytic — no compilation).

    Per (mesh, strategy): bytes/device/step on the fast tier (ICI, inner
    axes) and across DCN (the `pod` outer axis), from each strategy's own
    `bytes_per_device` model at the paper's full-batch regime, plus the
    autotuner's wire-cost ranking (each tier's bytes charged at that
    tier's bandwidth, `repro.api.autotune`) — the per-mesh winner, i.e.
    what `DPMRConfig.distribution="auto"` would pick, is marked `*`. The
    multi rows are where the hierarchical family earns its keep: its DCN
    bytes are the table block (or a sparsified fraction of it for
    `hier_a2a+topk`), not the shuffled request volume.
    """
    from repro.api import autotune
    from repro.api.strategies import StrategyContext
    from repro.configs.base import DPMRConfig
    from repro.core import dpmr

    cfg = DPMRConfig(num_features=feature_space, max_features_per_sample=k)
    rows = []
    # geometry of make_production_mesh: single (16,16); multi (2,16,16)
    for mesh_kind, p, po in (("single", 256, 1), ("multi", 512, 2)):
        cap = dpmr.capacity_for_shards(cfg, global_batch // p, p)
        ctx = StrategyContext(axes=(), num_shards=p,
                              block_size=-(-feature_space // p),
                              capacity=cap, outer_shards=po,
                              topk_frac=cfg.topk_frac)
        ranked = autotune.score_strategies(ctx)
        winner = ranked[0].name
        for rank, s in enumerate(ranked, start=1):
            rows.append({"mesh": mesh_kind, "strategy": s.name,
                         "shards": p, "pods": po, "capacity": cap,
                         "inner_bytes": int(s.wire.inner),
                         "outer_bytes": int(s.wire.outer),
                         "total_bytes": int(s.wire.total),
                         "cost_us": s.cost_s * 1e6, "rank": rank,
                         "lossy": s.lossy, "chosen": s.name == winner})
    print(f"{'mesh':>7s} {'strategy':>18s} {'ICI B/dev':>12s} "
          f"{'DCN B/dev':>12s} {'total':>12s} {'cost us':>9s} "
          f"{'rank':>4s}")
    for r in rows:
        mark = " *" if r["chosen"] else ("  " if not r["lossy"] else " ~")
        print(f"{r['mesh']:>7s} {r['strategy']:>18s} "
              f"{r['inner_bytes']:>12.3e} {r['outer_bytes']:>12.3e} "
              f"{r['total_bytes']:>12.3e} {r['cost_us']:>9.1f} "
              f"{r['rank']:>4d}{mark}")
    print("  * = autotuner's pick (distribution=\"auto\"); "
          "~ = lossy (error-feedback carry)")
    return rows


def _probe_config(cfg, n: int):
    """Reduced-DEPTH same-width config with n 'units' + the real unit count.

    A unit is whatever repeats: a layer (dense/moe/vlm), an enc+dec layer
    pair (whisper), a mamba group + shared block (zamba), an mLSTM+sLSTM
    pair (xlstm). Costs are affine in units, so two probes extrapolate
    exactly (attention/SSD inner scans are python-unrolled via
    models.layers.PROBE_UNROLL so nothing hides in a while body).
    """
    import dataclasses

    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=n, encoder_layers=n), \
            cfg.num_layers
    if cfg.family == "hybrid":
        every = max(cfg.attn_every, 1)
        return dataclasses.replace(cfg, num_layers=n * every), \
            cfg.num_layers // every
    if cfg.family == "ssm":
        pair = max(cfg.slstm_every, 1)
        return dataclasses.replace(cfg, num_layers=n * pair), \
            cfg.num_layers // pair
    return dataclasses.replace(cfg, num_layers=n), cfg.num_layers


def _parse_overrides(s: str) -> dict:
    """'attn_mode=cp,microbatches=4' -> dict with typed values."""
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=")
        if v in ("True", "False"):
            out[k] = v == "True"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def run_probe(arch: str, shape_name: str, overrides: str = "") -> dict:
    """Unrolled 1-unit and 2-unit cost probes on the single-pod mesh."""
    import dataclasses

    import jax

    from repro import compat

    from repro.configs import SHAPES, TrainConfig
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_production_mesh
    from repro.models import layers as layers_mod
    from repro.models import registry
    from repro.sharding import tree_sds, tree_shardings
    from repro.train import serve, trainer

    spec0 = registry.get_spec(arch)
    shape = SHAPES[shape_name]
    if shape_name not in spec0.supported_shapes:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": spec0.skip_reason}

    layers_mod.PROBE_UNROLL = True
    mesh = make_production_mesh(multi_pod=False)
    tc = TrainConfig()
    # probe at MICROBATCH size: the real step is `micro` sequential passes,
    # so step cost = micro x extrapolated probe cost (exact for both the
    # batch-linear activation collectives and the per-pass param gathers)
    ovr = _parse_overrides(overrides)
    batch_dm = ovr.pop("batch_dm", False)
    micro = ovr.pop("microbatches", None) or (
        _parallel_for(arch, shape_name, "single").microbatches
        if shape.kind == "train" else 1)
    if shape.kind == "train" and shape.global_batch % micro == 0:
        shape = dataclasses.replace(
            shape, global_batch=shape.global_batch // micro)
    out = {"arch": arch, "shape": shape_name, "status": "ok",
           "kind": shape.kind, "microbatches": micro,
           "overrides": overrides}
    rules = None
    repl_vocab = ovr.pop("replicate_vocab", False)
    if batch_dm or repl_vocab:
        from repro import sharding as shd
        rules = dict(shd.DEFAULT_RULES)
        if batch_dm:
            rules["batch"] = ("pod", "data", "model")
        if repl_vocab:
            rules["vocab"] = ()
    try:
        with compat.set_mesh(mesh):
            for n in (1, 2):
                cfg, units = _probe_config(spec0.cfg, n)
                spec = dataclasses.replace(spec0, cfg=cfg)
                parallel = ParallelConfig(microbatches=1, remat="full",
                                          scan_layers=False, **ovr)
                if shape.kind == "train":
                    sdefs = trainer.state_defs(spec, cfg, tc, parallel)
                    bdefs = registry.batch_defs(spec, shape)
                    step = trainer.make_train_step(spec, cfg, tc, parallel,
                                                   mesh)
                    fn = jax.jit(step, in_shardings=(
                        tree_shardings(sdefs, mesh, rules),
                        tree_shardings(bdefs, mesh, rules)))
                    args = (tree_sds(sdefs), tree_sds(bdefs))
                elif shape.kind == "prefill":
                    pdefs = spec.defs(cfg)
                    bdefs = registry.batch_defs(spec, shape)
                    step = serve.make_prefill_step(spec, cfg, parallel)
                    fn = jax.jit(step, in_shardings=(
                        tree_shardings(pdefs, mesh),
                        tree_shardings(bdefs, mesh)))
                    args = (tree_sds(pdefs), tree_sds(bdefs))
                else:
                    pdefs = spec.defs(cfg)
                    bdefs = registry.batch_defs(spec, shape)

                    def step(params, cache, tokens):
                        return spec.decode_step(params, cache, tokens, cfg,
                                                unroll=True)

                    cache_sh = tree_shardings(bdefs["cache"], mesh)
                    fn = jax.jit(step, in_shardings=(
                        tree_shardings(pdefs, mesh),
                        cache_sh,
                        tree_shardings(bdefs["tokens"], mesh)),
                        # keep the returned cache in-place (production would
                        # also donate); otherwise GSPMD remats it under a
                        # fresh sharding = phantom collectives
                        out_shardings=(None, cache_sh))
                    args = (tree_sds(pdefs), tree_sds(bdefs["cache"]),
                            tree_sds(bdefs["tokens"]))
                lowered = fn.lower(*args)
                compiled = lowered.compile()
                cost = compiled.cost_analysis()
                colls = _collectives_from_hlo(compiled.as_text())
                agg = {}
                for c in colls:
                    a = agg.setdefault(c["op"], {"count": 0, "bytes": 0})
                    a["count"] += 1
                    a["bytes"] += c["bytes"]
                out[f"probe{n}"] = {
                    "flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1)),
                    "transcendentals": float(cost.get("transcendentals", 0)),
                    "collective_summary": agg,
                }
                out["units"] = units
    finally:
        layers_mod.PROBE_UNROLL = False
    print(json.dumps(out, indent=1))
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             collect_hlo: bool = True, overrides: str = "") -> dict:
    import dataclasses

    import jax

    from repro import compat

    from repro.configs import SHAPES, TrainConfig
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_production_mesh
    from repro.models import registry
    from repro.sharding import tree_sds, tree_shardings
    from repro.train import trainer

    t0 = time.time()
    spec = registry.get_spec(arch)
    cfg = spec.cfg
    shape = SHAPES[shape_name]
    if shape_name not in spec.supported_shapes:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": spec.skip_reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    parallel = _parallel_for(arch, shape_name, mesh_kind)
    if overrides:
        parallel = dataclasses.replace(parallel, **_parse_overrides(overrides))
    tc = TrainConfig()

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            sdefs = trainer.state_defs(spec, cfg, tc, parallel)
            bdefs = registry.batch_defs(spec, shape)
            step = trainer.make_train_step(spec, cfg, tc, parallel, mesh)
            in_sh = (tree_shardings(sdefs, mesh), tree_shardings(bdefs, mesh))
            args = (tree_sds(sdefs), tree_sds(bdefs))
            fn = jax.jit(step, in_shardings=in_sh)
        elif shape.kind == "prefill":
            pdefs = spec.defs(cfg)
            bdefs = registry.batch_defs(spec, shape)
            from repro.train import serve
            step = serve.make_prefill_step(spec, cfg, parallel)
            in_sh = (tree_shardings(pdefs, mesh), tree_shardings(bdefs, mesh))
            args = (tree_sds(pdefs), tree_sds(bdefs))
            fn = jax.jit(step, in_shardings=in_sh)
        else:  # decode
            pdefs = spec.defs(cfg)
            bdefs = registry.batch_defs(spec, shape)
            from repro.train import serve
            step = serve.make_decode_step(spec, cfg)
            cache_sh = tree_shardings(bdefs["cache"], mesh)
            in_sh = (tree_shardings(pdefs, mesh), cache_sh,
                     tree_shardings(bdefs["tokens"], mesh))
            args = (tree_sds(pdefs), tree_sds(bdefs["cache"]),
                    tree_sds(bdefs["tokens"]))
            fn = jax.jit(step, in_shardings=in_sh,
                         out_shardings=(None, cache_sh))

        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory_analysis": _mem_dict(mem),
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "cost_keys": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and abs(float(v)) < 1e30},
        }
        if collect_hlo:
            hlo = compiled.as_text()
            colls = _collectives_from_hlo(hlo)
            agg = {}
            for c in colls:
                k = c["op"]
                a = agg.setdefault(k, {"count": 0, "bytes": 0})
                a["count"] += 1
                a["bytes"] += c["bytes"]
            rec["collectives"] = colls
            rec["collective_summary"] = agg
            del hlo
        print(json.dumps({k: v for k, v in rec.items()
                          if k != "collectives"}, indent=1))
        return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _parallel_for(arch: str, shape_name: str, mesh_kind: str):
    """Per-cell parallel config: microbatching keeps activations in HBM."""
    from repro.configs.base import ParallelConfig

    micro = {
        ("llama3-405b", "train_4k"): 16,
        ("mixtral-8x22b", "train_4k"): 8,
        ("chameleon-34b", "train_4k"): 4,
        ("granite-34b", "train_4k"): 4,
        ("phi3.5-moe-42b-a6.6b", "train_4k"): 4,
        ("granite-8b", "train_4k"): 2,
        ("yi-6b", "train_4k"): 2,
        ("zamba2-2.7b", "train_4k"): 8,   # no SP inside SSM blocks: rely on
        ("xlstm-125m", "train_4k"): 2,    # grad accumulation for activations
        ("whisper-small", "train_4k"): 2,
    }.get((arch, shape_name), 1)
    accum = "bfloat16" if arch in ("llama3-405b", "mixtral-8x22b") else \
        "float32"
    return ParallelConfig(microbatches=micro, remat="full",
                          accum_dtype=accum)


CELLS_MESHES = ("single", "multi")


def all_cells():
    from repro.configs import ARCH_IDS, SHAPES
    from repro.models import registry

    cells = []
    for arch in ARCH_IDS:
        spec = registry.get_spec(arch)
        for shape in SHAPES:
            for mk in CELLS_MESHES:
                cells.append((arch, shape, mk,
                              shape in spec.supported_shapes))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:mesh  (runs in-process)")
    ap.add_argument("--strategies", action="store_true",
                    help="print the two-tier (ICI/DCN) wire model of every "
                         "registered distribution strategy on the "
                         "production mesh geometries")
    ap.add_argument("--probe", action="store_true",
                    help="run the 1/2-unit unrolled cost probes instead")
    ap.add_argument("--pconf", default="",
                    help="ParallelConfig overrides, e.g. attn_mode=cp")
    ap.add_argument("--tag", default="",
                    help="suffix for the probe result filename")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="both", choices=("single", "multi",
                                                       "both"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have results")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    if args.strategies:
        rows = run_strategy_wire()
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, "strategy_wire.json"),
                      "w") as f:
                json.dump(rows, f, indent=1)
        return

    if args.cell:
        parts = args.cell.split(":")
        arch, shape = parts[0], parts[1]
        if args.probe:
            rec = run_probe(arch, shape, overrides=args.pconf)
            suffix = "probe" + (f"_{args.tag}" if args.tag else "")
        else:
            mk = parts[2]
            rec = run_cell(arch, shape, mk, collect_hlo=not args.no_hlo,
                           overrides=args.pconf)
            suffix = mk + (f"_{args.tag}" if args.tag else "")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            safe = f"{arch}__{shape}__{suffix}".replace("/", "_")
            with open(os.path.join(args.out, safe + ".json"), "w") as f:
                json.dump(rec, f)
        return

    assert args.all
    os.makedirs(args.out, exist_ok=True)
    if args.probe:
        seen = set()
        for arch, shape, _, supported in all_cells():
            if (arch, shape) in seen:
                continue
            seen.add((arch, shape))
            safe = f"{arch}__{shape}__probe".replace("/", "_")
            path = os.path.join(args.out, safe + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip existing] {safe}")
                continue
            if not supported:
                from repro.models import registry
                spec = registry.get_spec(arch)
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "status": "skipped",
                               "reason": spec.skip_reason}, f)
                continue
            print(f"[probe] {safe}", flush=True)
            t0 = time.time()
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--cell",
                 f"{arch}:{shape}", "--probe", "--out", args.out],
                capture_output=True, text=True, timeout=args.timeout,
                env={**os.environ, "PYTHONPATH": "src"})
            if proc.returncode != 0:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "status": "error",
                               "stderr": proc.stderr[-4000:]}, f)
                print(f"[FAIL {time.time()-t0:.0f}s] {safe}\n"
                      f"{proc.stderr[-1500:]}")
            else:
                print(f"[ok {time.time()-t0:.0f}s] {safe}")
        return
    meshes = CELLS_MESHES if args.mesh == "both" else (args.mesh,)
    for arch, shape, mk, supported in all_cells():
        if mk not in meshes:
            continue
        safe = f"{arch}__{shape}__{mk}".replace("/", "_")
        path = os.path.join(args.out, safe + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip existing] {safe}")
            continue
        if not supported:
            from repro.models import registry
            spec = registry.get_spec(arch)
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mk,
                           "status": "skipped",
                           "reason": spec.skip_reason}, f)
            print(f"[skipped-by-design] {safe}")
            continue
        print(f"[run] {safe}", flush=True)
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--cell",
             f"{arch}:{shape}:{mk}", "--out", args.out]
            + (["--no-hlo"] if args.no_hlo else []),
            capture_output=True, text=True, timeout=args.timeout,
            env={**os.environ, "PYTHONPATH": "src"},
        )
        if proc.returncode != 0:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mk,
                           "status": "error",
                           "stderr": proc.stderr[-4000:]}, f)
            print(f"[FAIL {time.time()-t0:.0f}s] {safe}\n{proc.stderr[-2000:]}")
        else:
            print(f"[ok {time.time()-t0:.0f}s] {safe}")


if __name__ == "__main__":
    main()
