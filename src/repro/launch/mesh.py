"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init and then calls this.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    single pod:  (16, 16)    axes (data, model)   = 256 v5e chips
    multi pod :  (2, 16, 16) axes (pod, data, model) = 512 chips, `pod` crosses DCN
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return compat.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over (DP axes present in mesh)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
