"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init and then calls this.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    single pod:  (16, 16)    axes (data, model)   = 256 v5e chips
    multi pod :  (2, 16, 16) axes (pod, data, model) = 512 chips, `pod` crosses DCN
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return compat.make_mesh((data, model), ("data", "model"))


OUTER_AXES = ("pod",)   # mesh axes that cross DCN (inter-pod network)


def tier_axes(mesh) -> tuple:
    """Factor `mesh.axis_names` into the (outer, inner) wire tiers.

    Outer axes cross the slow inter-pod network (DCN); inner axes are the
    fast intra-pod interconnect (ICI). Hierarchical strategies rely on the
    linear device index over all axes decomposing as
    `outer_index * inner_shards + inner_index`, which holds iff the outer
    axes are a LEADING prefix of the mesh — enforced here.
    """
    names = tuple(mesh.axis_names)
    outer = tuple(a for a in names if a in OUTER_AXES)
    inner = tuple(a for a in names if a not in OUTER_AXES)
    if outer and names[:len(outer)] != outer:
        raise ValueError(
            f"outer (DCN) axes {outer} must lead the mesh, got {names}; "
            "construct meshes (pod, ...) first, as make_production_mesh "
            "does")
    return outer, inner


def tier_shards(mesh) -> tuple:
    """(outer_shards, inner_shards) device counts for the two tiers."""
    outer, inner = tier_axes(mesh)
    po = 1
    for a in outer:
        po *= int(mesh.shape[a])
    pi = 1
    for a in inner:
        pi *= int(mesh.shape[a])
    return po, pi


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over (DP axes present in mesh)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
