"""Serve-step builders: prefill and decode with sharded KV/state caches."""
from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig


def make_prefill_step(spec, cfg: ModelConfig,
                      parallel: ParallelConfig) -> Callable:
    def prefill_step(params, batch):
        return spec.prefill(params, batch, cfg, parallel)

    return prefill_step


def make_decode_step(spec, cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens):
        return spec.decode_step(params, cache, tokens, cfg)

    return decode_step


def greedy_decode(spec, cfg: ModelConfig, params, batch, steps: int,
                  parallel=None):
    """Prefill + greedy decode loop (host loop; serving example driver)."""
    decode = jax.jit(make_decode_step(spec, cfg))
    logits, cache = jax.jit(make_prefill_step(spec, cfg, parallel))(
        params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
