"""Train-step builder: loss, grad accumulation, clipping, sharded optimizer.

The step is pjit-auto over the mesh; parameters carry DPMR-dense (FSDP)
shardings from their logical axes, so XLA materializes the per-layer
all-gather (distributeParameters) inside the layer scan and reduce-scatters
gradients (the feature reduce) in backward — see core/fsdp.py for the
explicit equivalence proof.

Cross-pod gradient compression (ParallelConfig.compress_pod_grads): grads
are computed per pod under shard_map(axis_names={'pod'}) — GSPMD still
handles data/model inside — then reduced across pods with error-feedback
int8 (optim/compression.py).
"""
from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import sharding as shd
from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models import common
from repro.optim import compression, optimizers, schedules
from repro.sharding import Annotated

AUX_COEF = 0.01      # MoE load-balance loss weight


def state_defs(spec, cfg: ModelConfig, train_cfg: TrainConfig,
               parallel: ParallelConfig) -> dict:
    """Annotated defs for the full train state (params + opt + step)."""
    pd = spec.defs(cfg)
    opt = optimizers.get_optimizer(train_cfg.optimizer)
    defs = {
        "params": pd,
        "opt": opt.init_defs(pd, cfg.opt_dtype),
        "step": Annotated((), "int32", ()),
    }
    if parallel.compress_pod_grads:
        defs["err"] = jax.tree.map(
            lambda a: Annotated(a.shape, "float32", a.logical), pd,
            is_leaf=lambda x: isinstance(x, Annotated))
    return defs


def init_state(spec, cfg: ModelConfig, train_cfg: TrainConfig,
               parallel: ParallelConfig, key) -> dict:
    pd = spec.defs(cfg)
    params = shd.init_from_defs(pd, key, scale_fn=common.embed_init_scale)
    opt = optimizers.get_optimizer(train_cfg.optimizer)
    state = {"params": params, "opt": opt.init(params, cfg.opt_dtype),
             "step": jnp.zeros((), jnp.int32)}
    if parallel.compress_pod_grads:
        state["err"] = compression.init_error_state(params)
    return state


def make_loss_fn(spec, cfg: ModelConfig, parallel: ParallelConfig):
    def loss_fn(params, batch):
        logits, aux = spec.forward(params, batch, cfg, parallel)
        nll = common.cross_entropy(logits, batch["labels"])
        loss = nll + AUX_COEF * aux
        return loss, {"nll": nll, "aux": aux}

    return loss_fn


def _split_micro(batch: dict, k: int) -> dict:
    def sp(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(spec, cfg: ModelConfig, train_cfg: TrainConfig,
                    parallel: ParallelConfig, mesh) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(spec, cfg, parallel)
    opt = optimizers.get_optimizer(train_cfg.optimizer)
    sched = schedules.get_schedule(train_cfg)
    k = max(parallel.microbatches, 1)
    has_pod = "pod" in mesh.axis_names
    compress = parallel.compress_pod_grads and has_pod

    def grads_of(params, batch):
        if k == 1:
            (loss, m), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, loss, m
        micro = _split_micro(batch, k)

        def body(carry, mb):
            g_acc, l_acc, a_acc = carry
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (g_acc, l_acc + loss, a_acc + m["aux"]), None

        adt = jnp.dtype(parallel.accum_dtype)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
        (grads, loss, aux), _ = jax.lax.scan(
            body, (g0, jnp.float32(0.0), jnp.float32(0.0)), micro)
        grads = jax.tree.map(lambda g: g / k, grads)
        return grads, loss / k, {"nll": loss / k, "aux": aux / k}

    def apply(state, grads, loss, m):
        grads, gnorm = optimizers.clip_by_global_norm(
            grads, train_cfg.grad_clip)
        lr = sched(state["step"])
        params, opt_state = opt.update(grads, state["opt"], state["params"],
                                       lr, train_cfg)
        new = dict(state, params=params, opt=opt_state,
                   step=state["step"] + 1)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **m}
        return new, metrics

    if not compress:
        def train_step(state, batch):
            grads, loss, m = grads_of(state["params"], batch)
            return apply(state, grads, loss, m)
    else:
        def pod_body(params, err, batch):
            grads, loss, m = grads_of(params, batch)
            g_hat, new_err = compression.compress_tree_psum(
                grads, err, "pod")
            loss = jax.lax.pmean(loss, "pod")
            m = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), m)
            return g_hat, new_err, loss, m

        def train_step(state, batch):
            pspec = jax.tree.map(lambda _: P(), state["params"])
            bspec = jax.tree.map(lambda _: P("pod"), batch)
            body = compat.shard_map(
                pod_body, mesh=mesh,
                in_specs=(pspec, pspec, bspec),
                out_specs=(pspec, pspec, P(), jax.tree.map(lambda _: P(),
                                                           {"nll": 0,
                                                            "aux": 0})),
                axis_names={"pod"}, check_vma=False)
            g_hat, new_err, loss, m = body(state["params"], state["err"],
                                           batch)
            state = dict(state, err=new_err)
            return apply(state, g_hat, loss, m)

    return train_step


def shardings_for_state(defs, mesh):
    return shd.tree_shardings(defs, mesh)


def batch_shardings(batch_defs, mesh):
    return shd.tree_shardings(batch_defs, mesh)
