"""GPipe-style pipeline parallelism over a `pipe` mesh axis.

Layers are split into S stages; stage s's parameters live on pipe-shard s
(stacked leading dim sharded over `pipe`). Microbatches stream through the
fill/drain schedule — T = M + S - 1 ticks; at tick t stage s computes
microbatch t - s — with stage boundaries crossed by jax.lax.ppermute.
Backward differentiates straight through (ppermute's transpose is the
reverse permute), giving the GPipe fill/drain backward automatically.

This is the optional PP axis for depth-dominated models where FSDP+TP
leaves too little per-device memory; it composes with the data axis (shard
microbatches over `data` inside the stage_fn). The 40-cell grid uses
FSDP+TP(+SP/CP) — PP is exercised by tests/test_pipeline.py and available
via make_pp_mesh.

Bubble fraction = (S-1)/(M+S-1); pick M >= 4S to keep it under 20%.
"""
from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def make_pp_mesh(pipe: int, data: int = 1):
    if data == 1:
        return compat.make_mesh((pipe,), ("pipe",))
    return compat.make_mesh((pipe, data), ("pipe", "data"))


def pipeline_apply(stage_params, micro_in, stage_fn: Callable, mesh,
                   axis: str = "pipe"):
    """Run microbatches through the pipeline.

    stage_params: pytree with leading dim = n_stages (sharded over `axis`).
    micro_in:     (M, B_mu, ...) microbatch inputs (replicated over `axis`).
    stage_fn:     (params_slice, x) -> y, same x/y shape (a stage of layers).

    Returns (M, B_mu, ...) outputs (replicated).
    """
    n_stages = int(mesh.shape[axis])
    m = micro_in.shape[0]
    ticks = m + n_stages - 1

    def per_shard(params_local, micro):
        # params_local: (1, ...) this stage's slice;  micro: (M, B, ...)
        p_loc = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        last = n_stages - 1
        perm_fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (clamped; masked when invalid)
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(stage == 0,
                             jax.lax.dynamic_index_in_dim(
                                 micro, mb_idx, 0, keepdims=False),
                             buf)
            y = stage_fn(p_loc, x_in)
            # drain: last stage writes its result for microbatch t-(S-1)
            out_idx = jnp.clip(t - last, 0, m - 1)
            valid = (t >= last) & (t - last < m)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(valid & (stage == last), y,
                                jax.lax.dynamic_index_in_dim(
                                    outs, out_idx, 0, keepdims=False)),
                out_idx, 0)
            # boundary transfer to the next stage
            nxt = jax.lax.ppermute(y, axis, perm_fwd)
            return (nxt, upd), None

        buf0 = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros_like(micro)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(ticks))
        # only the LAST stage holds real outputs; broadcast them to all
        # pipe shards so the result is replicated (psum of masked outs)
        outs = jax.lax.psum(
            jnp.where(stage == last, outs, jnp.zeros_like(outs)), axis)
        return outs

    fn = compat.shard_map(
        per_shard, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
        out_specs=P(),
        axis_names={axis}, check_vma=False)
    return fn(stage_params, micro_in)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
