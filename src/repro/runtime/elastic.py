"""Elastic rescaling: move a training/DPMR state between meshes.

Dense state (params/opt): checkpoints hold full logical arrays, so restoring
under the new mesh's shardings is a device_put (ckpt/checkpointer.py). This
module adds the DPMR sparse-face case, where the parameter table's PADDED
length depends on the shard count (F rounded up to a multiple of P): growing
or shrinking the mesh re-pads the table and re-shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import DPMRConfig
from repro.core import dpmr


def reshard_tree(tree, shardings):
    """device_put every leaf under the new sharding (full logical arrays)."""
    return jax.tree.map(jax.device_put, tree, shardings)


def reshard_dpmr_state(state: dpmr.DPMRState, cfg: DPMRConfig, new_mesh
                       ) -> dpmr.DPMRState:
    """Re-pad + re-shard a DPMRState for `new_mesh` (elastic scale up/down)."""
    f_new = dpmr.padded_features(cfg, new_mesh)
    axes = tuple(new_mesh.axis_names)
    shard = NamedSharding(new_mesh, P(axes))
    rep = NamedSharding(new_mesh, P())

    def repad(x):
        x = jax.device_get(x)
        if x.shape[0] < f_new:
            x = jnp.pad(x, (0, f_new - x.shape[0]))
        elif x.shape[0] > f_new:
            # shrinking is only valid if the tail is padding (beyond
            # cfg.num_features); assert to avoid silent weight loss
            assert x.shape[0] - (x.shape[0] - f_new) >= cfg.num_features, (
                "cannot shrink below the real feature space")
            x = x[:f_new]
        return x

    # the strategy carry (e.g. compression error feedback) is per-DEVICE
    # state, meaningless under a different shard count — reset to zeros of
    # the new mesh's geometry (safe: it is an optimization residual, not
    # model state; the next steps rebuild it)
    p_new = dpmr.num_shards(new_mesh)
    strat = jnp.zeros((p_new * dpmr.strategy_carry_len(cfg, new_mesh),),
                      jnp.float32)
    return dpmr.DPMRState(
        cold=jax.device_put(repad(state.cold), shard),
        hot=jax.device_put(jax.device_get(state.hot), rep),
        hot_ids=jax.device_put(jax.device_get(state.hot_ids), rep),
        cold_acc=jax.device_put(repad(state.cold_acc), shard),
        hot_acc=jax.device_put(jax.device_get(state.hot_acc), rep),
        step=jax.device_put(jax.device_get(state.step), rep),
        strat=jax.device_put(strat, shard),
    )
