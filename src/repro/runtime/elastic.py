"""Elastic rescaling: move a training/DPMR state between meshes.

Dense state (params/opt): checkpoints hold full logical arrays, so restoring
under the new mesh's shardings is a device_put (ckpt/checkpointer.py). This
module adds the DPMR sparse-face case, where the parameter table's PADDED
length depends on the shard count (F rounded up to a multiple of P): growing
or shrinking the mesh re-pads the table and re-shards — and the data-plane
case (`reshard_data_state`), where the loader cursor's host-local step was
recorded against one shard assignment and the new host count needs a fresh
one.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.data.ownership import reassign_state
from repro.runtime.multiprocess import host_value


def reshard_tree(tree, shardings):
    """device_put every leaf under the new sharding (full logical arrays)."""
    return jax.tree.map(jax.device_put, tree, shardings)


def reshard_dpmr_state(state: dpmr.DPMRState, cfg: DPMRConfig, new_mesh
                       ) -> dpmr.DPMRState:
    """Re-pad + re-shard a DPMRState for `new_mesh` (elastic scale up/down)."""
    f_new = dpmr.padded_features(cfg, new_mesh)
    axes = tuple(new_mesh.axis_names)
    shard = NamedSharding(new_mesh, P(axes))
    rep = NamedSharding(new_mesh, P())

    def repad(x):
        x = host_value(x)     # collective gather under real multi-process
        if x.shape[0] < f_new:
            x = jnp.pad(x, (0, f_new - x.shape[0]))
        elif x.shape[0] > f_new:
            # shrinking is only valid if the tail is padding (beyond
            # cfg.num_features); assert to avoid silent weight loss
            assert x.shape[0] - (x.shape[0] - f_new) >= cfg.num_features, (
                "cannot shrink below the real feature space")
            x = x[:f_new]
        return x

    # the strategy carry (compressed_reduce's quantization error feedback,
    # topk_reduce's sparsification residual) is per-DEVICE state,
    # meaningless under a different shard count — reset to zeros of the new
    # mesh's geometry (safe: it is an optimization residual, not model
    # state; the next steps rebuild it). strategy_carry_len resolves the
    # new per-device length through the strategy's own init_carry.
    p_new = dpmr.num_shards(new_mesh)
    strat = jnp.zeros((p_new * dpmr.strategy_carry_len(cfg, new_mesh),),
                      jnp.float32)
    return dpmr.DPMRState(
        cold=jax.device_put(repad(state.cold), shard),
        hot=jax.device_put(host_value(state.hot), rep),
        hot_ids=jax.device_put(host_value(state.hot_ids), rep),
        cold_acc=jax.device_put(repad(state.cold_acc), shard),
        hot_acc=jax.device_put(host_value(state.hot_acc), rep),
        step=jax.device_put(host_value(state.step), rep),
        strat=jax.device_put(strat, shard),
    )


def reshard_data_state(data_state: dict, num_hosts: int,
                       host_index: int | None = None) -> dict:
    """Rewrite a loader `state_dict()` (a checkpoint's `extra["data"]`) for
    a NEW data-plane host count — the input-face analogue of
    `reshard_dpmr_state`.

    The epoch (and with it the per-epoch shuffle permutations) survives;
    the host-local step resets to the epoch start, and the restoring
    loader recomputes its own chunk assignment, so every chunk is owned
    exactly once under the new geometry and none are dropped — the same
    correct-but-rebuilt contract as the strategy-carry reset above.
    Equivalent to `loader.load_state_dict(state,
    on_host_change="reassign")`; use this form when rewriting the state
    before the new loaders exist (e.g. a checkpoint-surgery script)."""
    return reassign_state(data_state, num_hosts, host_index)
