"""Real multi-process execution: one global mesh over N OS processes.

Everything multi-host in this repo used to be single-process *emulation*
(`launch/train.py --hosts H --host-id h`: one process serving one host's
shard of the data plane). This module stands up the real thing — N
processes, one `jax.distributed` coordinator, one GLOBAL mesh whose
devices span every process — while keeping the training loop, the
`ShardAssignment` data plane, and the checkpoint story unchanged.

The CPU recipe (verified on this container's jax/jaxlib):

  1. every process forces its LOCAL device count *before* jax initializes
     (`XLA_FLAGS=--xla_force_host_platform_device_count=<local>`; 4 global
     devices over 2 processes = 2 local devices each);
  2. CPU collectives go through gloo — but ONLY when `num_processes > 1`:
     setting `jax_cpu_collectives_implementation` in a single-process run
     breaks backend init (the CPU client then demands a distributed
     client that does not exist);
  3. `jax.distributed.initialize(coordinator, num_processes, process_id)`
     before the first computation; process 0 hosts the coordinator.

Data flows exactly as the ownership plane prescribes: process h *is*
data-plane host h — its `ShardedLoader` materializes only the batches
`ShardAssignment` assigns to host h, and `global_batch_placement` glues
the per-host rows into one global array per step
(`jax.make_array_from_process_local_data`): process h's local devices
hold rows `[h*B, (h+1)*B)` of the `H*B`-row global batch, the same rows
the single-process emulation concatenates. That is why a real H-process
run is bit-identical (final parameters, deterministic eval) to
`--hosts H --host-id -1` emulation at the same geometry: the jitted step
sees the same global arrays under the same sharding either way. (The
`pmean` loss *metric* may differ by ~1 ulp on a few steps — cross-process
reduction order — which is why parity checks hash parameters, not the
step-path metric; see docs/DISTRIBUTED.md.)
"""
from __future__ import annotations

import dataclasses
import os

__all__ = ["ProcessContext", "initialize", "context", "is_primary",
           "host_value", "barrier", "global_batch_placement",
           "emulate_all_hosts"]

_CONTEXT: "ProcessContext | None" = None


@dataclasses.dataclass(frozen=True)
class ProcessContext:
    """What `initialize` established (or the single-process default)."""

    num_processes: int
    process_id: int
    local_device_count: int
    coordinator: str = ""

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1

    @property
    def is_primary(self) -> bool:
        """Process 0 — the coordinator host and the only checkpoint writer."""
        return self.process_id == 0


def _force_local_device_count(n: int) -> None:
    """Pin this process's emulated CPU device count. Must run before jax
    initializes a backend — the flag is read once at backend init."""
    import jax

    flag = f"--xla_force_host_platform_device_count={n}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        flags = " ".join(f for f in flags.split()
                         if "xla_force_host_platform_device_count" not in f)
    os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()
    backends = getattr(getattr(jax, "_src", None), "xla_bridge", None)
    if backends is not None and getattr(backends, "_backends", None):
        raise RuntimeError(
            "multiprocess.initialize(local_device_count=...) must run "
            "before the first jax computation — the backend is already "
            "initialized and XLA_FLAGS can no longer take effect")


def initialize(coordinator: str = "", num_processes: int = 1,
               process_id: int = 0,
               local_device_count: int | None = None) -> ProcessContext:
    """Bootstrap this process's slice of the global runtime.

    Single-process (`num_processes == 1`): optionally pins the emulated
    device count and does NOT touch the collectives config (see module
    docstring, step 2). Multi-process: configures gloo and joins the
    coordinator at `coordinator` ("host:port"; process 0 serves it).
    Idempotent per process; returns the `ProcessContext` that `context()`
    will keep handing out.
    """
    global _CONTEXT
    if _CONTEXT is not None:
        return _CONTEXT
    if local_device_count is not None:
        _force_local_device_count(local_device_count)
    import jax

    if num_processes > 1:
        if not coordinator:
            raise ValueError("num_processes > 1 needs a coordinator "
                             "address (host:port)")
        if not 0 <= process_id < num_processes:
            raise ValueError(f"process_id {process_id} out of range for "
                             f"{num_processes} processes")
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
    _CONTEXT = ProcessContext(num_processes=int(num_processes),
                              process_id=int(process_id),
                              local_device_count=jax.local_device_count(),
                              coordinator=coordinator)
    return _CONTEXT


def context() -> ProcessContext:
    """The active context: whatever `initialize` established, else a
    default reflecting jax's own view (always 1 process in runs that never
    called `initialize`)."""
    if _CONTEXT is not None:
        return _CONTEXT
    import jax

    return ProcessContext(num_processes=jax.process_count(),
                          process_id=jax.process_index(),
                          local_device_count=jax.local_device_count())


def is_primary() -> bool:
    """True on the single process that owns externally-visible side
    effects (checkpoint writes, log lines meant to appear once)."""
    import jax

    return jax.process_index() == 0


def host_value(x):
    """Fetch any array — process-local or global — to host memory as
    numpy, on EVERY process.

    Single-process (and fully-replicated global) arrays are a plain
    `device_get`; a global array sharded across processes is gathered
    with `multihost_utils.process_allgather` (collective: all processes
    must call this together). This is the one seam checkpointing and
    `predict` need to work unchanged under real multi-process execution.
    """
    import jax
    import numpy as np

    if isinstance(x, jax.Array) and not x.is_fully_addressable \
            and not x.sharding.is_fully_replicated:
        from jax.experimental import multihost_utils

        return np.asarray(multihost_utils.process_allgather(x, tiled=True))
    return np.asarray(jax.device_get(x))


def barrier(name: str = "repro_barrier") -> None:
    """Cross-process sync point (no-op single-process)."""
    import jax

    if jax.process_count() > 1:
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(name)


def global_batch_placement(mesh, num_processes: int | None = None):
    """Placement callable for a `ShardedLoader` in a real H-process run.

    Each process's loader serves B host-local rows per step; the returned
    callable assembles them into H*B-row GLOBAL arrays sharded over all
    mesh axes — process h's rows land on its own local devices at offset
    h*B (`ShardAssignment.global_rows`), matching the concatenation order
    of the single-process emulation. The arrays carry the exact
    `NamedSharding` the engine's `put_batch` targets, so they pass through
    placement untouched. Safe to call from the loader's prefetch thread
    (`make_array_from_process_local_data` is process-local, not a
    collective).
    """
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec

    h = jax.process_count() if num_processes is None else num_processes
    sharding = NamedSharding(mesh, PartitionSpec(tuple(mesh.axis_names)))
    if h == 1:
        return lambda batch: batch      # emulation: put_batch places it

    def place(batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            local = np.asarray(v)
            out[k] = jax.make_array_from_process_local_data(
                sharding, local, (local.shape[0] * h,) + local.shape[1:])
        return out

    return place


class _AllHostsSource:
    """The parity baseline: one process serving EVERY host's stream.

    `batch(s)` concatenates `src.batch(s*H + h)` for h = 0..H-1 — exactly
    the global batch a real H-process run assembles at step s (stride
    ownership: host h owns batches h, h+H, ...). Chunk-owned file corpora
    interleave differently per host and have no single-stream equivalent;
    use a real multi-process run for those.
    """

    def __init__(self, source, num_hosts: int):
        kind = getattr(source, "owned_shards", None)
        if kind is not None and \
                source.owned_shards(0, num_hosts).kind != "stride":
            raise ValueError(
                "all-hosts emulation is defined for stride-owned sources "
                "only; chunk-owned corpora need a real multi-process run")
        self.source = source
        self.num_hosts = int(num_hosts)
        self.batch_size = source.batch_size * self.num_hosts
        self.num_batches = source.num_batches // self.num_hosts

    def batch(self, index: int) -> dict:
        import numpy as np

        parts = [self.source.batch(index * self.num_hosts + h)
                 for h in range(self.num_hosts)]
        return {k: np.concatenate([np.asarray(p[k]) for p in parts])
                for k in parts[0]}


def emulate_all_hosts(source, num_hosts: int):
    """Wrap a stride-owned `DataSource` so one process serves the
    concatenated per-step global batch of all `num_hosts` hosts
    (`launch/train.py --hosts H --host-id -1`)."""
    return _AllHostsSource(source, num_hosts)
