"""Fault tolerance: retries, preemption-graceful save, straggler watchdog,
failure injection for tests.

At 1000+ nodes the failure model is: (a) preemption signals (graceful), (b)
hard node loss (restart from checkpoint, possibly on fewer nodes — see
runtime/elastic.py), (c) stragglers (slow HBM/ICI on one chip stalls the
SPMD step). The host-side pieces here cover the coordinator's half of each:
checkpoint cadence + signal-triggered save, bounded retry-with-restore, and
a step-time watchdog that flags outliers for the scheduler to evict.
"""
from __future__ import annotations

import collections
from collections.abc import Callable
import logging
import signal
import statistics
import threading
import time

log = logging.getLogger("repro.ft")


class PreemptionGuard:
    """Sets a flag on SIGTERM/SIGINT so the train loop can save and exit."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        log.warning("preemption signal %s received", signum)
        self._flag.set()

    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self):         # for tests
        self._flag.set()


class StragglerWatchdog:
    """Tracks per-step wall time; flags steps > `factor` x rolling median.

    On a real pod the flagged host/chip id would be reported to the cluster
    scheduler for eviction; here we record and expose the events.
    """

    def __init__(self, window: int = 50, factor: float = 3.0):
        self.times = collections.deque(maxlen=window)
        self.factor = factor
        self.events = []
        self._t0: float | None = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int):
        if self._t0 is None:
            return
        dt = time.monotonic() - self._t0
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.factor * med:
                self.events.append({"step": step, "seconds": dt,
                                    "median": med})
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            step, dt, med)
        self.times.append(dt)


class FailureInjector:
    """Deterministic failure injection for integration tests."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.failed = []

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failed.append(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_with_restarts(make_loop: Callable[[int | None], int],
                      max_restarts: int = 3) -> int:
    """Run `make_loop(resume_step)` restarting on failure.

    make_loop returns the last completed step; on exception we restart from
    whatever the checkpointer has. Returns the final step."""
    restarts = 0
    last = None
    while True:
        try:
            return make_loop(last)
        except Exception as e:  # noqa: BLE001 — the point is to survive
            restarts += 1
            log.warning("training failed (%s); restart %d/%d",
                        e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
            last = None  # loop must re-read the checkpoint
