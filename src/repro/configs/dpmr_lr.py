"""The paper's own workload: distributed sparse logistic regression.

Scaled from the paper's 50B-feature / 20B-sample Hadoop run to a hashed
1M-feature space; the DPMR engine itself is feature-count agnostic (the
parameter table is sharded by feature over the `model` axis).
"""
from repro.configs.base import DPMRConfig

CONFIG = DPMRConfig(
    num_features=1 << 20,
    max_features_per_sample=64,
    hot_threshold=1e-3,
    max_hot=512,
    learning_rate=0.5,
    iterations=4,
    distribution="a2a",
)
