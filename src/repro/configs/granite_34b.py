"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
Llama-arch code model with multi-query attention.  [arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",          # granite-34b is a GPT-BigCode-style 2-mat MLP
)
