"""Configuration dataclasses for the repro framework.

Everything here is a frozen dataclass so configs are hashable and can be used
as static arguments to jitted step builders.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e) used by the roofline analysis.
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per ICI link direction
HBM_BYTES = 16 * 1024**3      # v5e HBM capacity


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (one instance per assigned arch)."""

    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # Attention
    sliding_window: int = 0         # 0 = full attention (Mixtral uses SWA)
    qk_norm: bool = False           # chameleon-style qk layernorm

    # SSM / hybrid (zamba2)
    ssm_state: int = 0
    ssm_heads: int = 0              # 0 -> num_heads
    ssm_expand: int = 2
    attn_every: int = 0             # hybrid: shared attention block every N layers

    # xLSTM
    slstm_every: int = 0            # every Nth block is sLSTM (rest mLSTM)

    # Encoder-decoder (whisper)
    encoder_layers: int = 0

    # MLP flavour
    mlp_type: str = "swiglu"        # swiglu (3 mats) | gelu (2 mats)

    # Numerics
    dtype: str = "bfloat16"         # activation dtype
    param_dtype: str = "float32"    # master parameter dtype
    opt_dtype: str = "float32"      # optimizer moment dtype
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Modality frontend stub: if True, input_specs() provides precomputed
    # frame/patch embeddings instead of token ids for the encoder side.
    frontend_stub: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or self.num_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qo = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * qo + 2 * d * kv + qo * d
        if self.family == "ssm":                      # xLSTM-style blocks
            per_layer = _xlstm_block_params(self)
        elif self.family == "hybrid":
            per_layer = _mamba_block_params(self)
            # shared attention block amortized over layers it serves
            n_attn = self.num_layers // max(self.attn_every, 1)
            shared = attn + 3 * d * f
            return (self.num_layers * per_layer + n_attn * shared
                    + v * d * (1 if self.tie_embeddings else 2))
        else:
            mats = 3 if self.mlp_type == "swiglu" else 2
            mlp = mats * d * f
            if self.num_experts:
                mlp = self.num_experts * mats * d * f + d * self.num_experts
            per_layer = attn + mlp
        n_layers = self.num_layers + self.encoder_layers
        embed = v * d * (1 if self.tie_embeddings else 2)
        return n_layers * per_layer + embed

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mats = 3 if self.mlp_type == "swiglu" else 2
        dense_mlp = self.num_experts * mats * d * f
        active_mlp = self.experts_per_token * mats * d * f
        return self.param_count() - self.num_layers * (dense_mlp - active_mlp)


def _xlstm_block_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    # mLSTM block: up-proj 2x, qkv, gates, down-proj (approximate, matches model defs)
    return 2 * d * 2 * d + 4 * (2 * d) * (2 * d) // 4 + 2 * d * d


def _mamba_block_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    e = cfg.ssm_expand
    di = e * d
    n = cfg.ssm_state
    g = max(1, cfg.resolved_ssm_heads // 4)
    return d * 2 * di + di * d + 2 * g * n * d + di  # in/out proj + B,C proj + dt


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh."""

    fsdp_axis: str = "data"          # DPMR dense face: params sharded here
    tensor_axis: str = "model"       # TP / expert-parallel / feature-owner axis
    dp_axes: tuple[str, ...] = ("pod", "data")
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    microbatches: int = 1            # grad-accumulation chunks per step
    seq_shard: bool = True           # SP: residual stream sharded over model
    accum_dtype: str = "float32"     # grad-accumulator dtype (bf16 on giants)
    attn_mode: str = "auto"          # auto (GSPMD) | cp (context-parallel:
    #                                  q sequence-sharded, kv-only gather)
    moe_group: int = 512             # MoE group-limited dispatch group size
    # DPMR sparse face for embedding tables
    sparse_embed: bool = False
    # gradient compression on the cross-pod DP axis
    compress_pod_grads: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # sgd | momentum | adam | adamw
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class DPMRConfig:
    """Paper-faithful sparse-face configuration (logistic regression)."""

    num_features: int = 1 << 20      # hashed feature space
    max_features_per_sample: int = 64
    hot_threshold: float = 0.001     # features with freq above this are replicated
    max_hot: int = 512               # cap on replicated hot features
    learning_rate: float = 0.5
    iterations: int = 4
    distribution: str = "a2a"        # any name in the repro.api strategy
    #                                  registry (a2a | allgather |
    #                                  psum_scatter | hier_a2a |
    #                                  compressed_reduce | topk_reduce |
    #                                  overlap_a2a | compositions like
    #                                  hier_a2a+topk / hier_a2a+int8 |
    #                                  user-registered), or the sentinel
    #                                  "auto": repro.api.autotune picks the
    #                                  cheapest strategy for the mesh from
    #                                  the analytic per-tier wire models
    #                                  (core.dpmr.resolve_distribution)
    topk_frac: float = 0.25          # topk_reduce: fraction of the per-
    #                                  destination capacity slots whose
    #                                  largest-|g| gradients go on the wire
    #                                  (k = ceil(topk_frac * cap)); the rest
    #                                  feed the error-feedback residual.
    #                                  1.0 degenerates to the full shuffle.
    kernel_impl: str = "xla"         # lowering of the routing hot path
    #                                  (repro.kernels.ops.KERNEL_IMPLS):
    #                                  "xla" = the pure-jnp reference chain
    #                                  (default; CPU/GPU-safe), "pallas" =
    #                                  the TPU kernels (fused select+pack,
    #                                  masked-matmul segment-sum reduce),
    #                                  "pallas_interpret" = kernels run in
    #                                  python on CPU (testing). Threaded to
    #                                  every strategy via
    #                                  StrategyContext.kernel_impl.
    grad_scale: str = "mean"         # mean | sum (paper: sum, full-batch GD)
    optimizer: str = "sgd"           # any name in optim.SPARSE_OPTIMIZERS
    #                                  (sgd = the paper's GD; adagrad /
    #                                  momentum via the `optimize(para,grad)`
    #                                  hook, Alg. 7:12, with DPMR-sharded
    #                                  accumulator state)
    adagrad_eps: float = 1e-6
    momentum: float = 0.9            # sparse momentum optimizer coefficient
    schedule: str = "constant"       # any name in optim.schedules.SCHEDULES
    warmup_steps: int = 0            # schedule parameters (warmup_cosine)
    total_steps: int = 0
    seed: int = 0
