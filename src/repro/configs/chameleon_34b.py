"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536,
early-fusion VQ image tokens, qk-norm.  [arXiv:2405.09818]

The image tokenizer is a STUB: VQ image tokens share the 65536-entry text
vocab, so input_specs() supplies ordinary token ids (early fusion).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
)
