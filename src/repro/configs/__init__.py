"""Arch-id -> config registry.

Architecture ids use the assignment's spelling (dashes/dots); module names
use underscores.
"""
from repro.configs.base import (
    SHAPES,
    DPMRConfig,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
)

_ARCH_MODULES = {
    "whisper-small": "whisper_small",
    "mixtral-8x22b": "mixtral_8x22b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-8b": "granite_8b",
    "yi-6b": "yi_6b",
    "llama3-405b": "llama3_405b",
    "granite-34b": "granite_34b",
    "zamba2-2.7b": "zamba2_2p7b",
    "xlstm-125m": "xlstm_125m",
    "chameleon-34b": "chameleon_34b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_dpmr_config() -> DPMRConfig:
    from repro.configs.dpmr_lr import CONFIG

    return CONFIG


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "DPMRConfig",
    "ModelConfig",
    "ParallelConfig",
    "ShapeConfig",
    "TrainConfig",
    "get_config",
    "get_dpmr_config",
]
