"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
Mamba2 backbone + shared attention blocks.  [arXiv:2411.15242]

ssm_state=64. Shared attention+MLP block applied every `attn_every` layers
(weights shared across applications, the zamba signature).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    attn_every=6,
)
