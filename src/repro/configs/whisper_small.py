"""whisper-small [audio]: enc-dec transformer backbone, conv frontend stubbed.

12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865  [arXiv:2212.04356]
The audio conv frontend is a STUB: input_specs() provides precomputed frame
embeddings for the encoder; the decoder consumes token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    mlp_type="gelu",
    frontend_stub=True,
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions, not RoPE
)
