"""xlstm-125m [ssm]: 12L d_model=768 4H (kv=4) d_ff=0 vocab=50304,
sLSTM + mLSTM blocks.  [arXiv:2405.04517]

d_ff=0: block capacity lives in the mLSTM/sLSTM up/down projections
(projection factor 2), per the xLSTM block design. Every `slstm_every`-th
block is an sLSTM (recurrent scalar memory); the rest are mLSTM (matrix
memory, parallelizable).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=2,
    tie_embeddings=True,
)
