"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783]

405B params on a 256-chip v5e pod requires bf16 master params + bf16 Adam
moments (8 bytes/param sharded 256-way ~ 12.7 GB/chip); production would use
more chips or quantized moments — recorded in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
)
