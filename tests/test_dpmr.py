"""DPMR engine tests: routing oracles, hot sharding, convergence, strategy
equivalence (a2a == allgather == dense oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DPMRConfig
from repro.core import dpmr, hot_sharding, sparse, sparse_lr
from repro.data import sparse_corpus
from repro.launch.mesh import make_host_mesh

F = 1 << 12
SPEC = sparse_corpus.CorpusSpec(num_features=F, features_per_sample=16,
                                signal_features=256, seed=0)


def _cfg(**kw):
    base = dict(num_features=F, max_features_per_sample=16, iterations=2,
                learning_rate=1.0, max_hot=32)
    base.update(kw)
    return DPMRConfig(**base)


def _dense_lr_oracle(batches, f, lr, iters, grad_scale="mean"):
    """Numpy full-batch GD logistic regression (the ground truth)."""
    theta = np.zeros(f, np.float32)
    for _ in range(iters):
        acc = np.zeros(f, np.float64)
        nb = 0
        for b in batches:
            ids, vals, y = b["ids"], b["vals"], b["labels"]
            th = theta[np.clip(ids, 0, None)] * (ids >= 0)
            logits = (th * vals).sum(1)
            p = 1 / (1 + np.exp(-logits))
            g = vals * (p - y)[:, None]
            if grad_scale == "mean":
                g = g / ids.shape[0]
            np.add.at(acc, np.clip(ids, 0, f - 1),
                      np.where(ids >= 0, g, 0.0))
            nb += 1
        theta = theta - lr * (acc / nb).astype(np.float32)
    return theta


def test_routing_roundtrip_oracle():
    rng = np.random.default_rng(0)
    p, f = 4, 64
    block, cap = f // p, 24
    ids = rng.integers(-1, f, size=(57,)).astype(np.int32)
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    assert int(r.overflow) == 0
    table = rng.normal(size=(f,)).astype(np.float32)
    resp = np.zeros((p, cap), np.float32)
    req = np.asarray(r.req_ids)
    for o in range(p):
        resp[o] = np.where(req[o] >= 0, table[np.clip(req[o], 0, f - 1)], 0)
    vals = sparse.route_return(r, jnp.asarray(resp))
    expect = np.where(ids >= 0, table[np.clip(ids, 0, f - 1)], 0)
    np.testing.assert_allclose(np.asarray(vals), expect, rtol=1e-6)


def test_grad_combine_oracle():
    rng = np.random.default_rng(1)
    p, f = 4, 64
    block, cap = f // p, 24
    ids = rng.integers(-1, f, size=(57,)).astype(np.int32)
    grads = rng.normal(size=ids.shape).astype(np.float32)
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    send = np.asarray(sparse.combine_grads(r, jnp.asarray(grads)))
    got = np.zeros(f)
    req = np.asarray(r.req_ids)
    for o in range(p):
        for c in range(cap):
            if req[o, c] >= 0:
                got[req[o, c]] += send[o, c]
    want = np.zeros(f)
    np.add.at(want, np.clip(ids, 0, f - 1), np.where(ids >= 0, grads, 0))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_overflow_counted_when_capacity_too_small():
    ids = jnp.arange(32, dtype=jnp.int32)     # 32 unique, all owner 0
    r = sparse.route_build(ids, 2, 64, 8)     # cap 8 < 32 uniques
    assert int(r.overflow) == 24


def test_hot_split():
    counts = jnp.asarray([100, 1, 50, 1, 1, 80, 1, 1], jnp.int32)
    hot = hot_sharding.select_hot(counts, threshold=0.1, max_hot=4)
    hot_np = np.asarray(hot)
    assert set(hot_np[hot_np < 2**31 - 1]) == {0, 2, 5}
    ids = jnp.asarray([0, 1, 5, -1, 3], jnp.int32)
    slot, is_hot, cold = hot_sharding.split_hot(ids, hot)
    assert list(np.asarray(is_hot)) == [True, False, True, False, False]
    assert list(np.asarray(cold)) == [-1, 1, -1, -1, 3]


@pytest.mark.parametrize("distribution", ["a2a", "allgather"])
def test_dpmr_matches_dense_oracle(distribution):
    """The full staged pipeline == numpy logistic regression GD."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution=distribution, max_hot=16)
    batches = list(sparse_corpus.batches(SPEC, 128, 3))
    hot = sparse_lr.hot_ids_from_corpus(cfg, batches, mesh)
    with jax.set_mesh(mesh):
        out = sparse_lr.dpmr_train(cfg, mesh, lambda: iter(batches), 128,
                                   hot_ids=hot)
    f = dpmr.padded_features(cfg, mesh)
    oracle = _dense_lr_oracle(batches, f, cfg.learning_rate, cfg.iterations)
    # reassemble full theta: cold + hot written back at hot_ids
    theta = np.asarray(out["state"].cold).copy()
    hids = np.asarray(out["state"].hot_ids)
    hvals = np.asarray(out["state"].hot)
    real = hids < 2**31 - 1
    theta[hids[real]] = hvals[real]
    np.testing.assert_allclose(theta, oracle, atol=2e-4)


def test_a2a_equals_allgather():
    mesh = make_host_mesh(1, 1)
    batches = list(sparse_corpus.batches(SPEC, 128, 3))
    outs = {}
    for dist in ("a2a", "allgather"):
        cfg = _cfg(distribution=dist)
        with jax.set_mesh(mesh):
            outs[dist] = np.asarray(sparse_lr.dpmr_train(
                cfg, mesh, lambda: iter(batches), 128)["state"].cold)
    np.testing.assert_allclose(outs["a2a"], outs["allgather"], atol=1e-5)


def test_sgd_training_reduces_loss_and_learns():
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(optimizer="adagrad", learning_rate=2.0)
    with jax.set_mesh(mesh):
        out = sparse_lr.dpmr_train_sgd(
            cfg, mesh, sparse_corpus.batches(SPEC, 256, 40), 256)
        test = list(sparse_corpus.batches(SPEC, 256, 52, start=50))
        ev = sparse_lr.evaluate(out["state"], out["fns"], test, mesh)
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first - 0.01, (first, last)
    assert ev["f_avg"] > 0.5, ev


def test_classify_probabilities_valid():
    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    with jax.set_mesh(mesh):
        out = sparse_lr.dpmr_train_sgd(
            cfg, mesh, sparse_corpus.batches(SPEC, 128, 5), 128)
        b = sparse_corpus.make_batch(SPEC, 128, seed=777)
        probs = sparse_lr.dpmr_classify(
            out["state"], out["fns"], {"ids": b["ids"], "vals": b["vals"]},
            mesh)
    assert probs.shape == (128,)
    assert np.all((probs >= 0) & (probs <= 1))


def test_engine_with_pallas_kernels_matches_jnp():
    """The full DPMR pipeline with the (interpreted) Pallas sigmoid-grad
    kernel is bit-identical to the jnp oracle path — the kernel is a true
    drop-in for the computeGradients map body."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    batches = list(sparse_corpus.batches(SPEC, 128, 3))
    outs = {}
    for impl in ("jnp", "pallas_interpret"):
        with jax.set_mesh(mesh):
            outs[impl] = np.asarray(sparse_lr.dpmr_train(
                cfg, mesh, lambda: iter(batches), 128,
                kernel_impl=impl)["state"].cold)
    np.testing.assert_array_equal(outs["jnp"], outs["pallas_interpret"])


def test_segment_kernel_as_combiner():
    """The MXU segment-sum kernel can replace the scatter-add combiner:
    scattering its run-end totals delivers identical owner sums."""
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    p, f = 4, 64
    block, cap = f // p, 64
    ids = rng.integers(-1, f, size=(57,)).astype(np.int32)
    grads = rng.normal(size=ids.shape).astype(np.float32)
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    # scatter-add combiner (engine default)
    send_a = np.asarray(sparse.combine_grads(r, jnp.asarray(grads)))
    # kernel combiner: segment totals on the sorted stream, scatter run ends
    g_sorted = jnp.asarray(grads)[r.order]
    g_sorted = jnp.where(r.keep_s, g_sorted, 0.0)
    ids_sorted = jnp.where(r.keep_s, jnp.asarray(ids)[r.order], -1)
    totals = ops.segment_sum_sorted(ids_sorted, g_sorted,
                                    impl="pallas_interpret", block=16)
    send_b = jnp.zeros((p, cap), jnp.float32).at[
        jnp.where(r.keep_s, r.owner_s, p), r.pos_s
    ].add(totals, mode="drop")
    np.testing.assert_allclose(send_a, np.asarray(send_b), atol=1e-5)


def test_elastic_reshard_roundtrip():
    from repro.runtime.elastic import reshard_dpmr_state

    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    with jax.set_mesh(mesh):
        out = sparse_lr.dpmr_train_sgd(
            cfg, mesh, sparse_corpus.batches(SPEC, 128, 3), 128)
    state = out["state"]
    state2 = reshard_dpmr_state(state, cfg, mesh)
    np.testing.assert_array_equal(np.asarray(state.cold),
                                  np.asarray(state2.cold))
