"""DPMR engine tests: routing oracles, hot sharding, convergence, strategy
equivalence (a2a == allgather == psum_scatter == hier_a2a == dense oracle,
compressed_reduce within quantization error), the two-tier wire model, the
DPMREngine facade, capacity/overflow accounting, and checkpoint roundtrip
(including the persistent strategy carry)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DistributionStrategy, DPMREngine, WireBytes,
                       get_strategy, hot_ids_from_corpus, list_strategies,
                       register_strategy)
from repro.api.strategies import StrategyContext
from repro.configs.base import DPMRConfig
from repro.core import dpmr, hot_sharding, sparse
from repro.data import get_source, sparse_corpus
from repro.launch.mesh import make_host_mesh, tier_axes, tier_shards

F = 1 << 12
SPEC = sparse_corpus.CorpusSpec(num_features=F, features_per_sample=16,
                                signal_features=256, seed=0)
# strategies that are EXACT (bit-identical parameters when nothing
# overflows); compressed_reduce / topk_reduce are lossy and tested for
# parity instead
STRATEGIES = ("a2a", "allgather", "psum_scatter", "hier_a2a",
              "overlap_a2a")


def _batches(batch_size, num_batches, start=0):
    """Batches [start, num_batches) — the legacy `sparse_corpus.batches`
    call convention, served by the zipf_sparse data source."""
    src = get_source("zipf_sparse", spec=SPEC, batch_size=batch_size)
    return src.iter_batches(start=start, limit=num_batches - start)


def _cfg(**kw):
    base = dict(num_features=F, max_features_per_sample=16, iterations=2,
                learning_rate=1.0, max_hot=32)
    base.update(kw)
    return DPMRConfig(**base)


def _dense_lr_oracle(batches, f, lr, iters, grad_scale="mean"):
    """Numpy full-batch GD logistic regression (the ground truth)."""
    theta = np.zeros(f, np.float32)
    for _ in range(iters):
        acc = np.zeros(f, np.float64)
        nb = 0
        for b in batches:
            ids, vals, y = b["ids"], b["vals"], b["labels"]
            th = theta[np.clip(ids, 0, None)] * (ids >= 0)
            logits = (th * vals).sum(1)
            p = 1 / (1 + np.exp(-logits))
            g = vals * (p - y)[:, None]
            if grad_scale == "mean":
                g = g / ids.shape[0]
            np.add.at(acc, np.clip(ids, 0, f - 1),
                      np.where(ids >= 0, g, 0.0))
            nb += 1
        theta = theta - lr * (acc / nb).astype(np.float32)
    return theta


# ---------------------------------------------------------------------------
# pure routing / hot-sharding oracles
# ---------------------------------------------------------------------------


def test_routing_roundtrip_oracle():
    rng = np.random.default_rng(0)
    p, f = 4, 64
    block, cap = f // p, 24
    ids = rng.integers(-1, f, size=(57,)).astype(np.int32)
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    assert int(r.overflow) == 0
    table = rng.normal(size=(f,)).astype(np.float32)
    resp = np.zeros((p, cap), np.float32)
    req = np.asarray(r.req_ids)
    for o in range(p):
        resp[o] = np.where(req[o] >= 0, table[np.clip(req[o], 0, f - 1)], 0)
    vals = sparse.route_return(r, jnp.asarray(resp))
    expect = np.where(ids >= 0, table[np.clip(ids, 0, f - 1)], 0)
    np.testing.assert_allclose(np.asarray(vals), expect, rtol=1e-6)


def test_grad_combine_oracle():
    rng = np.random.default_rng(1)
    p, f = 4, 64
    block, cap = f // p, 24
    ids = rng.integers(-1, f, size=(57,)).astype(np.int32)
    grads = rng.normal(size=ids.shape).astype(np.float32)
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    send = np.asarray(sparse.combine_grads(r, jnp.asarray(grads)))
    got = np.zeros(f)
    req = np.asarray(r.req_ids)
    for o in range(p):
        for c in range(cap):
            if req[o, c] >= 0:
                got[req[o, c]] += send[o, c]
    want = np.zeros(f)
    np.add.at(want, np.clip(ids, 0, f - 1), np.where(ids >= 0, grads, 0))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_overflow_counted_when_capacity_too_small():
    ids = jnp.arange(32, dtype=jnp.int32)     # 32 unique, all owner 0
    r = sparse.route_build(ids, 2, 64, 8)     # cap 8 < 32 uniques
    assert int(r.overflow) == 24


def test_hot_split():
    counts = jnp.asarray([100, 1, 50, 1, 1, 80, 1, 1], jnp.int32)
    hot = hot_sharding.select_hot(counts, threshold=0.1, max_hot=4)
    hot_np = np.asarray(hot)
    assert set(hot_np[hot_np < 2**31 - 1]) == {0, 2, 5}
    ids = jnp.asarray([0, 1, 5, -1, 3], jnp.int32)
    slot, is_hot, cold = hot_sharding.split_hot(ids, hot)
    assert list(np.asarray(is_hot)) == [True, False, True, False, False]
    assert list(np.asarray(cold)) == [-1, 1, -1, -1, 3]


# ---------------------------------------------------------------------------
# capacity model
# ---------------------------------------------------------------------------


def test_capacity_model():
    """capacity(): >= 16, multiple of 8, ~factor x uniform mean, <= n."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    n = 128 * cfg.max_features_per_sample
    cap = dpmr.capacity(cfg, 128, mesh)
    assert cap == dpmr.capacity_for_shards(cfg, 128, dpmr.num_shards(mesh))
    assert cap % 8 == 0 or cap == n
    assert 16 <= cap <= n
    # tiny factor clamps to the floor of 16; huge factor clamps to n
    assert dpmr.capacity(cfg, 128, mesh, factor=1e-9) == 16
    assert dpmr.capacity(cfg, 128, mesh, factor=1e9) == n
    # analytic shard counts: capacity shrinks ~1/p
    c32 = dpmr.capacity_for_shards(cfg, 2048, 32)
    c256 = dpmr.capacity_for_shards(cfg, 2048, 256)
    assert c256 < c32


@pytest.mark.parametrize("distribution", ["a2a", "psum_scatter",
                                          "hier_a2a", "compressed_reduce",
                                          "topk_reduce", "overlap_a2a"])
def test_overflow_metric_nonzero_at_tiny_capacity(distribution):
    """Sparse-forward strategies report dropped uniques through the
    `overflow` metric when cap_factor is forced tiny, and zero at the
    default factor."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution=distribution)
    batch = sparse_corpus.make_batch(SPEC, 128, 0)

    tiny = DPMREngine(cfg, mesh, cap_factor=1e-9)
    assert tiny.step_fns(128).capacity == 16
    m = tiny.train_step(batch)
    assert m["overflow"] > 0, m

    dflt = DPMREngine(cfg, mesh)
    m = dflt.train_step(batch)
    assert m["overflow"] == 0, m


def test_overflow_metric_zero_for_allgather():
    """The ship-the-table strategy has no capacity to overflow."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution="allgather")
    batch = sparse_corpus.make_batch(SPEC, 128, 0)
    m = DPMREngine(cfg, mesh, cap_factor=1e-9).train_step(batch)
    assert m["overflow"] == 0, m


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------


def test_strategy_registry():
    assert set(STRATEGIES) <= set(list_strategies())
    assert get_strategy("a2a").name == "a2a"
    with pytest.raises(KeyError):
        get_strategy("nope")

    @register_strategy("test_alias_a2a")
    class AliasA2A(type(get_strategy("a2a"))):
        pass

    assert "test_alias_a2a" in list_strategies()
    assert isinstance(get_strategy("test_alias_a2a"), DistributionStrategy)


def test_registered_strategy_trains():
    """A user-registered strategy is selectable via cfg.distribution."""
    register_strategy("test_custom", get_strategy("a2a"))
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(distribution="test_custom"), mesh)
    hist = eng.fit_sgd(_batches(128, 2))
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])


# ---------------------------------------------------------------------------
# engine vs dense oracle / strategy equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("distribution", STRATEGIES)
def test_dpmr_matches_dense_oracle(distribution):
    """The full staged pipeline == numpy logistic regression GD."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution=distribution, max_hot=16)
    batches = list(_batches(128, 3))
    hot = hot_ids_from_corpus(cfg, batches, mesh)
    eng = DPMREngine(cfg, mesh, hot_ids=hot)
    eng.fit(lambda: iter(batches))
    f = dpmr.padded_features(cfg, mesh)
    oracle = _dense_lr_oracle(batches, f, cfg.learning_rate, cfg.iterations)
    # reassemble full theta: cold + hot written back at hot_ids
    theta = np.asarray(eng.state.cold).copy()
    hids = np.asarray(eng.state.hot_ids)
    hvals = np.asarray(eng.state.hot)
    real = hids < 2**31 - 1
    theta[hids[real]] = hvals[real]
    np.testing.assert_allclose(theta, oracle, atol=2e-4)


def test_strategies_agree():
    """All registered built-in strategies produce identical parameters and
    losses on a 1-device mesh (they only differ in wire bytes)."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 3))
    colds, hists = {}, {}
    for dist in STRATEGIES:
        eng = DPMREngine(_cfg(distribution=dist), mesh)
        hists[dist] = [h["loss"] for h in eng.fit(lambda: iter(batches))]
        colds[dist] = np.asarray(eng.state.cold)
    for dist in STRATEGIES[1:]:
        np.testing.assert_allclose(colds[STRATEGIES[0]], colds[dist],
                                   atol=1e-5)
        np.testing.assert_allclose(hists[STRATEGIES[0]], hists[dist],
                                   rtol=1e-6)


def test_sgd_training_reduces_loss_and_learns():
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(optimizer="adagrad", learning_rate=2.0)
    eng = DPMREngine(cfg, mesh)
    history = eng.fit_sgd(_batches(256, 40))
    ev = eng.evaluate(list(_batches(256, 52, start=50)))
    first = np.mean([h["loss"] for h in history[:5]])
    last = np.mean([h["loss"] for h in history[-5:]])
    assert last < first - 0.01, (first, last)
    assert ev["f_avg"] > 0.5, ev


def test_classify_probabilities_valid():
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    eng.fit_sgd(_batches(128, 5))
    b = sparse_corpus.make_batch(SPEC, 128, seed=777)
    probs = eng.predict({"ids": b["ids"], "vals": b["vals"]})
    assert probs.shape == (128,)
    assert np.all((probs >= 0) & (probs <= 1))


# ---------------------------------------------------------------------------
# two-tier wire model + hierarchical / compressed strategies
# ---------------------------------------------------------------------------


def test_bytes_per_device_two_tier_contract():
    """Every registered built-in returns WireBytes; on a single-tier
    geometry nothing crosses DCN and the totals match the received-bytes
    models ((P-1) peers — a device's own chunk never travels);
    inner + outer == total always."""
    p, cap, block = 256, 64, 1 << 14
    flat = StrategyContext(axes=(), num_shards=p, block_size=block,
                           capacity=cap)
    received = {"a2a": 3 * (p - 1) * cap * 4,
                "allgather": 2 * block * (p - 1) * 4,
                "psum_scatter": 2 * (p - 1) * cap * 4
                + block * (p - 1) * 4}
    for name in list_strategies():
        wb = get_strategy(name).bytes_per_device(flat)
        assert isinstance(wb, WireBytes), name
        assert wb.outer == 0, (name, wb)
        assert wb.total == wb.inner + wb.outer
        if name in received:
            assert wb.total == received[name], (name, wb)


def test_hier_a2a_crosses_dcn_with_fewer_bytes():
    """The headline property: on a multi-pod geometry at the paper's
    full-batch regime, hier_a2a's DCN bytes (table block mirror + per-pod
    partials) are strictly below flat a2a's (cross-pod request volume)."""
    p, po = 512, 2
    cfg = DPMRConfig(num_features=1 << 30, max_features_per_sample=64)
    cap = dpmr.capacity_for_shards(cfg, (1 << 24) // p, p)
    ctx = StrategyContext(axes=(), num_shards=p,
                          block_size=(1 << 30) // p, capacity=cap,
                          outer_shards=po)
    a2a = get_strategy("a2a").bytes_per_device(ctx)
    hier = get_strategy("hier_a2a").bytes_per_device(ctx)
    assert hier.outer < a2a.outer, (hier, a2a)
    # the trade: hier pays with MORE inner (ICI) volume, never less
    assert hier.inner >= a2a.inner


def test_strategy_context_exposes_mesh_tiers():
    """make_step_fns threads the (outer, inner) axis split of the mesh to
    the strategies via StepFns.ctx; a pod-less mesh has an empty outer
    tier."""
    mesh = make_host_mesh(1, 1)
    assert tier_axes(mesh) == ((), ("data", "model"))
    assert tier_shards(mesh) == (1, 1)
    fns = DPMREngine(_cfg(), mesh).step_fns(128)
    assert fns.ctx.axes == ("data", "model")
    assert fns.ctx.outer_axes == () and fns.ctx.outer_shards == 1
    assert fns.ctx.inner_axes == ("data", "model")
    assert fns.ctx.inner_shards == fns.ctx.num_shards == 1


def test_compressed_reduce_convergence_parity():
    """compressed_reduce (int8 reduce + error feedback) trains to within
    1% of a2a's final loss on the same SGD run."""
    mesh = make_host_mesh(1, 1)
    final = {}
    for dist in ("a2a", "compressed_reduce"):
        eng = DPMREngine(_cfg(distribution=dist, optimizer="adagrad",
                              learning_rate=2.0), mesh)
        hist = eng.fit_sgd(_batches(256, 40))
        final[dist] = np.mean([h["loss"] for h in hist[-5:]])
    rel = abs(final["compressed_reduce"] - final["a2a"]) / final["a2a"]
    assert rel < 0.01, final


def test_compressed_reduce_error_feedback_state():
    """The quantization residual lives in DPMRState.strat: zero at init,
    nonzero after a step, untouched by stateless strategies."""
    mesh = make_host_mesh(1, 1)
    batch = sparse_corpus.make_batch(SPEC, 128, 0)

    eng = DPMREngine(_cfg(distribution="compressed_reduce"), mesh)
    f = dpmr.padded_features(eng.cfg, mesh)
    assert eng.state.strat.shape == (f,)          # per-device (F,) carry
    assert float(jnp.abs(eng.state.strat).sum()) == 0.0
    eng.train_step(batch)
    assert float(jnp.abs(eng.state.strat).sum()) > 0.0

    plain = DPMREngine(_cfg(), mesh)              # stateless: placeholder
    assert plain.state.strat.shape == (1,)
    plain.train_step(batch)
    assert float(jnp.abs(plain.state.strat).sum()) == 0.0


def test_compressed_reduce_carry_checkpoint_roundtrip(tmp_path):
    """save()/restore() persists the error-feedback carry: a restored run
    continues bit-identically to the uninterrupted one (it would diverge
    if the carry were dropped)."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution="compressed_reduce", optimizer="adagrad",
               learning_rate=2.0)
    batches = list(_batches(128, 6))

    full = DPMREngine(cfg, mesh)
    full.fit_sgd(iter(batches))

    part = DPMREngine(cfg, mesh)
    part.fit_sgd(iter(batches[:3]))
    assert float(jnp.abs(part.state.strat).sum()) > 0.0
    part.save(str(tmp_path))

    resumed = DPMREngine(cfg, mesh)
    resumed.restore(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(part.state.strat),
                                  np.asarray(resumed.state.strat))
    resumed.fit_sgd(iter(batches[3:]))
    for a, b in zip(full.state, resumed.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_warns_on_strategy_mismatch(tmp_path):
    """A checkpoint trained under one strategy restored into an engine
    configured for another must not silently adopt the foreign carry."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(distribution="a2a"), mesh)
    eng.fit_sgd(_batches(128, 2))
    eng.save(str(tmp_path))
    other = DPMREngine(_cfg(distribution="psum_scatter"), mesh)
    with pytest.warns(RuntimeWarning, match="distribution"):
        other.restore(str(tmp_path))


def test_restore_unregistered_strategy_raises_value_error(tmp_path):
    """A checkpoint whose saved strategy is NOT in this process's registry
    (e.g. a session-local composition that was never re-registered) must
    fail with a ValueError naming the missing strategy — not leak the
    registry's KeyError."""
    from repro.api.strategies import _REGISTRY

    mesh = make_host_mesh(1, 1)
    register_strategy("ephemeral_xyz", get_strategy("a2a"))
    try:
        eng = DPMREngine(_cfg(distribution="ephemeral_xyz"), mesh)
        eng.fit_sgd(_batches(128, 2))
        eng.save(str(tmp_path))
    finally:
        _REGISTRY.pop("ephemeral_xyz", None)

    other = DPMREngine(_cfg(distribution="a2a"), mesh)
    with pytest.raises(ValueError, match="ephemeral_xyz"):
        other.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# topk_reduce / overlap_a2a: sparsified & overlap-aware exchanges
# ---------------------------------------------------------------------------


def test_overlap_a2a_bit_identical_to_a2a():
    """The micro-chunked exchange must change the SCHEDULE only: losses
    and parameters equal a2a's bit for bit (no float-order tolerance)."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 4))
    out = {}
    for dist in ("a2a", "overlap_a2a"):
        eng = DPMREngine(_cfg(distribution=dist), mesh)
        hist = eng.fit_sgd(iter(batches))
        out[dist] = (np.asarray(eng.state.cold),
                     [h["loss"] for h in hist])
    np.testing.assert_array_equal(out["a2a"][0], out["overlap_a2a"][0])
    assert out["a2a"][1] == out["overlap_a2a"][1]


def test_topk_frac_one_degenerates_to_a2a():
    """topk_frac=1.0 keeps every slot: parameters match a2a and the
    residual stays identically zero."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 3))
    ref = DPMREngine(_cfg(distribution="a2a"), mesh)
    ref.fit_sgd(iter(batches))
    full = DPMREngine(_cfg(distribution="topk_reduce", topk_frac=1.0), mesh)
    full.fit_sgd(iter(batches))
    np.testing.assert_allclose(np.asarray(ref.state.cold),
                               np.asarray(full.state.cold), atol=1e-6)
    assert float(jnp.abs(full.state.strat).sum()) == 0.0


def test_topk_error_feedback_state():
    """At a sparsifying fraction the dropped slots bank a residual in
    DPMRState.strat; it is per-device |F|-sized like compressed_reduce's."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution="topk_reduce", topk_frac=0.1)
    eng = DPMREngine(cfg, mesh)
    f = dpmr.padded_features(cfg, mesh)
    assert eng.state.strat.shape == (f,)
    assert float(jnp.abs(eng.state.strat).sum()) == 0.0
    eng.train_step(sparse_corpus.make_batch(SPEC, 128, 0))
    assert float(jnp.abs(eng.state.strat).sum()) > 0.0


def test_topk_reduce_convergence_parity():
    """Error feedback keeps topk_reduce within 1% of a2a's final loss on
    the SGD run (the tighter 0.1%-at-default gate lives in
    benchmarks/strategy_overlap.py)."""
    mesh = make_host_mesh(1, 1)
    final = {}
    for dist in ("a2a", "topk_reduce"):
        eng = DPMREngine(_cfg(distribution=dist, optimizer="adagrad",
                              learning_rate=2.0, topk_frac=0.1), mesh)
        hist = eng.fit_sgd(_batches(256, 40))
        final[dist] = np.mean([h["loss"] for h in hist[-5:]])
    rel = abs(final["topk_reduce"] - final["a2a"]) / final["a2a"]
    assert rel < 0.01, final


def test_stateful_strategies_exact_on_full_batch_fit():
    """The fit() accumulation path freezes the carry (fwd["accumulate"]);
    both lossy built-ins must fall back to their exact reduce there —
    parameters match a2a (topk even at an aggressive fraction), and the
    residual never accumulates (sparsifying/quantizing against a frozen
    carry would drop gradient mass / re-inject a restored residual once
    per accumulated batch)."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 3))
    ref = DPMREngine(_cfg(distribution="a2a"), mesh)
    ref.fit(lambda: iter(batches))
    for dist in ("topk_reduce", "compressed_reduce"):
        eng = DPMREngine(_cfg(distribution=dist, topk_frac=0.05), mesh)
        eng.fit(lambda: iter(batches))
        np.testing.assert_allclose(np.asarray(ref.state.cold),
                                   np.asarray(eng.state.cold), atol=1e-5)
        assert float(jnp.abs(eng.state.strat).sum()) == 0.0, dist


def test_restored_carry_frozen_through_fit():
    """A nonzero residual restored from an SGD run must ride through a
    fit() epoch untouched (re-injected zero times, not once per batch)."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 4))
    for dist in ("topk_reduce", "compressed_reduce"):
        eng = DPMREngine(_cfg(distribution=dist, topk_frac=0.05), mesh)
        eng.fit_sgd(iter(batches))            # builds a live residual
        before = np.asarray(eng.state.strat).copy()
        assert np.abs(before).sum() > 0.0, dist
        eng.fit(lambda: iter(batches), iterations=1)
        np.testing.assert_array_equal(before, np.asarray(eng.state.strat))


def test_topk_carry_checkpoint_roundtrip(tmp_path):
    """save()/restore() persists the sparsification residual bit-exactly:
    a restored run continues identically to the uninterrupted one."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution="topk_reduce", topk_frac=0.1,
               optimizer="adagrad", learning_rate=2.0)
    batches = list(_batches(128, 6))

    full = DPMREngine(cfg, mesh)
    full.fit_sgd(iter(batches))

    part = DPMREngine(cfg, mesh)
    part.fit_sgd(iter(batches[:3]))
    assert float(jnp.abs(part.state.strat).sum()) > 0.0
    part.save(str(tmp_path))

    resumed = DPMREngine(cfg, mesh)
    resumed.restore(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(part.state.strat),
                                  np.asarray(resumed.state.strat))
    resumed.fit_sgd(iter(batches[3:]))
    for a, b in zip(full.state, resumed.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_topk_carry_reset_on_elastic_reshard():
    """Elastic resharding must zero the residual (per-device state is
    meaningless under a new shard count) while keeping the parameters."""
    from repro.runtime.elastic import reshard_dpmr_state

    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution="topk_reduce", topk_frac=0.1)
    eng = DPMREngine(cfg, mesh)
    eng.fit_sgd(_batches(128, 3))
    assert float(jnp.abs(eng.state.strat).sum()) > 0.0
    new = reshard_dpmr_state(eng.state, cfg, mesh)
    assert float(jnp.abs(new.strat).sum()) == 0.0
    assert new.strat.shape == eng.state.strat.shape
    np.testing.assert_array_equal(np.asarray(new.cold),
                                  np.asarray(eng.state.cold))


def test_restore_warns_on_topk_frac_mismatch(tmp_path):
    """A topk_reduce residual accumulated at one sparsification level
    restored under another must be called out."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(distribution="topk_reduce", topk_frac=0.1), mesh)
    eng.fit_sgd(_batches(128, 2))
    eng.save(str(tmp_path))
    other = DPMREngine(_cfg(distribution="topk_reduce", topk_frac=0.5),
                       mesh)
    with pytest.warns(RuntimeWarning, match="topk_frac"):
        other.restore(str(tmp_path))


def test_topk_selection_helpers_oracle():
    """compression.topk_count / topk_mask against numpy ground truth."""
    from repro.optim import compression

    assert compression.topk_count(16, 0.25) == 4
    assert compression.topk_count(16, 1e-9) == 1      # floor at 1
    assert compression.topk_count(16, 1.0) == 16      # ceil at n
    assert compression.topk_count(10, 0.25) == 3      # ceil, not round
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    for k in (1, 7, 32):
        idx, mask = compression.topk_select(x, k)
        idx, mask = np.asarray(idx), np.asarray(mask)
        assert idx.shape == (5, k)
        assert mask.shape == x.shape and mask.sum(axis=1).tolist() == \
            [k] * 5
        np.testing.assert_array_equal(
            mask, np.asarray(compression.topk_mask(x, k)))
        for row, irow, mrow in zip(np.asarray(x), idx, mask, strict=True):
            top = set(sorted(row, reverse=True)[:k])
            assert set(row[mrow]) == top == set(row[irow])


def test_topk_and_overlap_wire_models():
    """topk_reduce cuts the reduce leg cap -> 2k pairs on BOTH tiers;
    overlap_a2a's bytes equal a2a's exactly (it buys schedule, not
    volume); ctx.topk_frac is threaded from DPMRConfig through StepFns."""
    from repro.optim import compression

    p, po, cap, block = 512, 2, 2048, 1 << 21
    for frac in (0.05, 0.25):
        ctx = StrategyContext(axes=(), num_shards=p, block_size=block,
                              capacity=cap, outer_shards=po,
                              topk_frac=frac)
        a2a = get_strategy("a2a").bytes_per_device(ctx)
        topk = get_strategy("topk_reduce").bytes_per_device(ctx)
        assert get_strategy("overlap_a2a").bytes_per_device(ctx) == a2a
        k = compression.topk_count(cap, frac)
        # forward legs match a2a's 2 buffers; reduce leg is k (val, id)
        # pairs per peer on each tier
        pi = ctx.inner_shards
        assert topk.inner == 2 * (pi - 1) * cap * 4 + (pi - 1) * k * 8
        assert topk.outer == 2 * (p - pi) * cap * 4 + (p - pi) * k * 8
        assert topk.total < a2a.total

    mesh = make_host_mesh(1, 1)
    fns = DPMREngine(_cfg(distribution="topk_reduce", topk_frac=0.125),
                     mesh).step_fns(128)
    assert fns.ctx.topk_frac == 0.125


# ---------------------------------------------------------------------------
# optimizer / schedule registries on the sparse face
# ---------------------------------------------------------------------------


def test_sparse_optimizer_registry():
    from repro.optim import optimizers

    assert {"sgd", "adagrad", "momentum"} <= set(
        optimizers.SPARSE_OPTIMIZERS)
    with pytest.raises(KeyError):
        optimizers.get_sparse_optimizer("nope")
    # momentum trains and differs from plain sgd
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(256, 10))
    colds = {}
    for opt in ("sgd", "momentum"):
        eng = DPMREngine(_cfg(optimizer=opt, learning_rate=0.5), mesh)
        eng.fit_sgd(iter(batches))
        colds[opt] = np.asarray(eng.state.cold)
    assert np.max(np.abs(colds["sgd"] - colds["momentum"])) > 1e-7


def test_schedule_registry_on_sparse_face():
    from repro.optim import schedules

    with pytest.raises(KeyError):
        schedules.get_schedule_by_name("nope", 1.0)
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(schedule="warmup_cosine", warmup_steps=2, total_steps=8,
               learning_rate=1.0)
    eng = DPMREngine(cfg, mesh)
    assert eng.learning_rate() == 0.0          # step 0 of warmup
    hist = eng.fit_sgd(_batches(256, 8))
    assert np.isfinite(hist[-1]["loss"])
    assert eng.learning_rate() < cfg.learning_rate   # cosine decayed


# ---------------------------------------------------------------------------
# checkpointing through the engine
# ---------------------------------------------------------------------------


def test_engine_save_restore_roundtrip(tmp_path):
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(optimizer="adagrad", learning_rate=2.0)
    eng = DPMREngine(cfg, mesh)
    eng.fit_sgd(_batches(128, 6))
    step = eng.save(str(tmp_path))
    assert step == 6

    eng2 = DPMREngine(cfg, mesh)
    manifest = eng2.restore(str(tmp_path))
    assert manifest["extra"]["kind"] == "dpmr_sparse"
    for a, b in zip(eng.state, eng2.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues identically from the restored state
    batch = sparse_corpus.make_batch(SPEC, 128, seed=99)
    m1 = eng.train_step(batch)
    m2 = eng2.train_step(batch)
    assert m1 == m2
    np.testing.assert_array_equal(np.asarray(eng.state.cold),
                                  np.asarray(eng2.state.cold))


# ---------------------------------------------------------------------------
# deprecated fn-dict surface is GONE (one-release deprecation completed)
# ---------------------------------------------------------------------------


def test_legacy_fn_dict_surface_removed():
    """core.sparse_lr and StepFns dict access finished their one-release
    deprecation in the PR that added the data plane."""
    with pytest.raises(ImportError):
        from repro.core import sparse_lr  # noqa: F401
    from repro.core import api as core_api

    for gone in ("dpmr_train", "dpmr_train_sgd", "dpmr_classify", "evaluate"):
        assert not hasattr(core_api, gone), gone
    assert callable(core_api.hot_ids_from_corpus)   # re-homed, still public

    mesh = make_host_mesh(1, 1)
    fns = DPMREngine(_cfg(), mesh).step_fns(128)
    with pytest.raises(TypeError):
        fns["train_step"]           # dict-style access removed
    assert callable(fns.train_step)


# ---------------------------------------------------------------------------
# engine regression guards (empty corpus, step-fns cache bound)
# ---------------------------------------------------------------------------


def test_fit_empty_corpus_raises_value_error():
    """fit() with a batch_iter_fn that yields nothing must raise a clear
    ValueError, not ZeroDivisionError (regression)."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    with pytest.raises(ValueError, match="no batches"):
        eng.fit(lambda: iter([]))


def test_step_fns_cache_is_lru_bounded():
    """Every distinct batch size compiles once, but only `max_cached_fns`
    entries are retained (bucketed serving traffic must not leak)."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh, max_cached_fns=2)
    for bs in (64, 128, 192):
        eng.step_fns(bs)
    assert list(eng._fns) == [128, 192]      # 64 evicted (least recent)
    eng.step_fns(128)                        # refresh 128
    eng.step_fns(64)                         # evicts 192
    assert list(eng._fns) == [128, 64]
    assert eng.fns is eng._fns[64]           # .fns == most recently used
    with pytest.raises(ValueError):
        DPMREngine(_cfg(), mesh, max_cached_fns=0)


# ---------------------------------------------------------------------------
# kernels / elastic integration
# ---------------------------------------------------------------------------


def test_engine_with_pallas_kernels_matches_jnp():
    """The full DPMR pipeline with the (interpreted) Pallas sigmoid-grad
    kernel is bit-identical to the jnp oracle path — the kernel is a true
    drop-in for the computeGradients map body."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 3))
    outs = {}
    for impl in ("jnp", "pallas_interpret"):
        eng = DPMREngine(_cfg(), mesh, kernel_impl=impl)
        eng.fit(lambda: iter(batches))
        outs[impl] = np.asarray(eng.state.cold)
    np.testing.assert_array_equal(outs["jnp"], outs["pallas_interpret"])


def test_segment_kernel_as_combiner():
    """The MXU segment-sum kernel can replace the scatter-add combiner:
    scattering its run-end totals delivers identical owner sums."""
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    p, f = 4, 64
    block, cap = f // p, 64
    ids = rng.integers(-1, f, size=(57,)).astype(np.int32)
    grads = rng.normal(size=ids.shape).astype(np.float32)
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    # scatter-add combiner (engine default)
    send_a = np.asarray(sparse.combine_grads(r, jnp.asarray(grads)))
    # kernel combiner: segment totals on the sorted stream, scatter run ends
    g_sorted = jnp.asarray(grads)[r.order]
    g_sorted = jnp.where(r.keep_s, g_sorted, 0.0)
    ids_sorted = jnp.where(r.keep_s, jnp.asarray(ids)[r.order], -1)
    totals = ops.segment_sum_sorted(ids_sorted, g_sorted,
                                    impl="pallas_interpret", block=16)
    send_b = jnp.zeros((p, cap), jnp.float32).at[
        jnp.where(r.keep_s, r.owner_s, p), r.pos_s
    ].add(totals, mode="drop")
    np.testing.assert_allclose(send_a, np.asarray(send_b), atol=1e-5)


def test_elastic_reshard_roundtrip():
    from repro.runtime.elastic import reshard_dpmr_state

    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    eng.fit_sgd(_batches(128, 3))
    state2 = reshard_dpmr_state(eng.state, eng.cfg, mesh)
    np.testing.assert_array_equal(np.asarray(eng.state.cold),
                                  np.asarray(state2.cold))