"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.api import DPMREngine, hot_ids_from_corpus
from repro.configs import ARCH_IDS, SHAPES
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.models import registry


@pytest.mark.slow
def test_paper_pipeline_end_to_end():
    """Algorithm 8 (train) + Algorithm 9 (classify): the full loop improves
    F over the majority-class baseline — the paper's Fig. 1 behaviour."""
    src = get_source("zipf_sparse", batch_size=512, num_features=1 << 14,
                     features_per_sample=32, signal_features=512, seed=0)
    cfg = DPMRConfig(num_features=1 << 14, max_features_per_sample=32,
                     iterations=8, learning_rate=2.0, max_hot=64,
                     optimizer="adagrad")
    mesh = make_host_mesh(1, 1)
    train = lambda: src.iter_batches(limit=8)
    test = list(src.iter_batches(start=50, limit=2))
    hot = hot_ids_from_corpus(cfg, train(), mesh)
    evals = []

    def ev(engine):
        m = engine.evaluate(test)
        evals.append(m)
        return m

    DPMREngine(cfg, mesh, hot_ids=hot).fit(train, eval_fn=ev)
    # converging: last F beats first F, and both classes predicted
    assert evals[-1]["f_avg"] > evals[0]["f_avg"]
    assert evals[-1]["f_pos"] > 0.6 and evals[-1]["f_neg"] > 0.3, evals[-1]


def test_all_archs_registered_with_shapes():
    """Deliverable (f): 10 archs x shape sets = the assigned 40-cell grid."""
    assert len(ARCH_IDS) == 10
    cells = 0
    for arch in ARCH_IDS:
        spec = registry.get_spec(arch)
        assert spec.cfg.name == arch
        assert set(spec.supported_shapes) <= set(SHAPES)
        cells += 4  # the assignment defines 4 shape cells per arch
        if len(spec.supported_shapes) < 4:
            assert spec.skip_reason  # skips must be justified
    assert cells == 40


def test_serve_greedy_decode_runs():
    from repro.configs.base import ParallelConfig, TrainConfig
    from repro.train import serve, trainer

    mesh = make_host_mesh(1, 1)
    cfg = registry.smoke_config("yi-6b")
    spec = registry.get_spec("yi-6b")
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, TrainConfig(optimizer="sgd"),
                                   ParallelConfig(), jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        toks = serve.greedy_decode(spec, cfg, state["params"], batch, 5,
                                   ParallelConfig(seq_shard=False))
    assert toks.shape == (2, 5)
    assert jnp.all((toks >= 0) & (toks < cfg.vocab_size))


def test_production_mesh_shapes():
    """make_production_mesh is a function (no import-time device usage)."""
    import inspect

    from repro.launch import mesh as mesh_mod

    assert inspect.isfunction(mesh_mod.make_production_mesh)
    src = inspect.getsource(mesh_mod)
    assert "(2, 16, 16)" in src and "(16, 16)" in src


def test_dryrun_collective_parser():
    from repro.launch.dryrun import _collectives_from_hlo

    hlo = """
  %ag = bf16[16,1024,512]{2,1,0} all-gather(%p), replica_groups=[16,16]<=[256]
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %a2a = f32[8,64]{1,0} all-to-all(%y), replica_groups=[2,128]<=[256]
  %other = f32[2] add(%a, %b)
"""
    cols = _collectives_from_hlo(hlo)
    kinds = sorted(c["op"] for c in cols)
    assert kinds == ["all-gather", "all-reduce", "all-to-all"]
    ag = [c for c in cols if c["op"] == "all-gather"][0]
    assert ag["bytes"] == 16 * 1024 * 512 * 2
    assert ag["group_size"] == 16
    ar = [c for c in cols if c["op"] == "all-reduce"][0]
    assert ar["group_size"] == 4


def test_hot_sharding_reduces_overflow():
    """Paper §4 claim: splitting out the Zipf head bounds the shuffle skew.

    Ownership is contiguous-block, so a Zipf head concentrated in one
    owner's block overflows a tight capacity; masking the head (replication
    = the paper's sub-feature sharding) makes the same capacity suffice."""
    from repro.core import hot_sharding, sparse

    rng = np.random.default_rng(3)
    f, p = 4096, 8
    block, cap = f // p, 24
    # Zipf-ish head: 60% of hits on 16 ids inside ONE owner block
    head = rng.integers(0, block // 4, size=600).astype(np.int32) % 16
    tail = rng.integers(0, f, size=400).astype(np.int32)
    ids = jnp.asarray(np.concatenate([head, tail]))

    counts = hot_sharding.feature_counts(ids, f)
    hot = hot_sharding.select_hot(counts, threshold=0.01, max_hot=32)
    _, _, cold = hot_sharding.split_hot(ids, hot)

    r_no = sparse.route_build(ids, p, block, cap)
    r_hot = sparse.route_build(cold, p, block, cap)
    assert int(r_no.overflow) > int(r_hot.overflow), (
        int(r_no.overflow), int(r_hot.overflow))
    # and the load imbalance diagnostic improves
    imb_no = float(hot_sharding.load_imbalance(ids, p, block))
    imb_hot = float(hot_sharding.load_imbalance(cold, p, block))
    assert imb_hot <= imb_no
