"""Universal strategy-conformance suite.

ONE parametrized harness, auto-discovered over `list_strategies()` —
including registered compositions (`hier_a2a+topk`, `hier_a2a+int8`) —
crossed with the audit geometries {1dev, pod8, multipod}. Registering a
new strategy or composition makes it appear here automatically; it cannot
merge without proving the full contract:

  analytic (no devices — jaxpr tracing on each geometry):
    * every rule in `repro.analysis.contracts` passes (W-MODEL, W-MATCH,
      W-OUTER, W-SINGLE, F-OVERFLOW, C-CARRY, A-FREEZE, A-EXACT)
    * declared `bytes_per_device` WireBytes == the auditor-extracted
      bytes on BOTH tiers, asserted explicitly per geometry
    * distribute's fwd dict carries a scalar int32 "overflow"; stateful
      strategies expose a 1-D f32 carry, return (grad, new_carry), and
      pass the carry through untouched on the accumulate path

  engine (real DPMREngine on the host mesh):
    * dense-oracle agreement on the accumulate (fit) path — EXACT for
      everyone, lossy strategies included, because the accumulate path
      must fall back to an exact reduce
    * SGD-path parity with a2a: bit-level for exact strategies, a
      documented loss tolerance for lossy (error-feedback) ones
    * overflow metric is 0 at default capacity
    * carry init shape/zeros, elastic-reshard reset
    * save()/restore() continues bit-exactly (carry included)

  multi-pod engine (slow, 8 emulated devices in a subprocess): the
  registered compositions train on a real (pod, data, model) mesh —
  fit() parameters match flat a2a, fit_sgd keeps a live namespaced
  carry of the composed length, elastic reshard zeroes it.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import trace as trace_mod
from repro.analysis.audit import build_contexts
from repro.analysis.contracts import check_strategy
from repro.analysis.wire import wire_total
from repro.api import (DPMREngine, get_strategy, hot_ids_from_corpus,
                       list_strategies)
from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.data import get_source, sparse_corpus
from repro.launch.mesh import make_host_mesh
from repro.runtime.elastic import reshard_dpmr_state

# captured at collection time: the built-in registry (other test modules
# register throwaway strategies at RUN time; those are theirs to test)
NAMES = list_strategies()
CONTEXTS = {a.name: a for a in build_contexts(production=False)}
GEOMETRIES = sorted(CONTEXTS)

F = 1 << 12
SPEC = sparse_corpus.CorpusSpec(num_features=F, features_per_sample=16,
                                signal_features=256, seed=0)

# documented SGD-path tolerance vs a2a for strategies that are lossy on
# the HOST mesh (error feedback trades per-step exactness for volume; the
# convergence gates live in test_dpmr / benchmarks). Strategies absent
# here must match a2a's parameters to float tolerance. Compositions are
# exact on a single pod: their lossy leg only exists when outer_shards>1.
SGD_LOSS_RTOL = {"compressed_reduce": 0.05, "topk_reduce": 0.05}


def _batches(batch_size, num_batches):
    src = get_source("zipf_sparse", spec=SPEC, batch_size=batch_size)
    return src.iter_batches(limit=num_batches)


def _cfg(**kw):
    base = dict(num_features=F, max_features_per_sample=16, iterations=2,
                learning_rate=1.0, max_hot=32)
    base.update(kw)
    return DPMRConfig(**base)


def _dense_lr_oracle(batches, f, lr, iters):
    """Numpy full-batch GD logistic regression (the ground truth)."""
    theta = np.zeros(f, np.float32)
    for _ in range(iters):
        acc = np.zeros(f, np.float64)
        nb = 0
        for b in batches:
            ids, vals, y = b["ids"], b["vals"], b["labels"]
            th = theta[np.clip(ids, 0, None)] * (ids >= 0)
            logits = (th * vals).sum(1)
            p = 1 / (1 + np.exp(-logits))
            g = vals * (p - y)[:, None] / ids.shape[0]
            np.add.at(acc, np.clip(ids, 0, f - 1),
                      np.where(ids >= 0, g, 0.0))
            nb += 1
        theta = theta - lr * (acc / nb).astype(np.float32)
    return theta


# ---------------------------------------------------------------------------
# analytic conformance: every strategy x every geometry, no devices
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def traced():
    """Per geometry: every strategy's trace + the exact strategies'
    reduce-path signature multisets (the A-EXACT reference set)."""
    out = {}
    for gname, actx in CONTEXTS.items():
        traces = {n: trace_mod.trace_strategy(get_strategy(n), actx.ctx,
                                              actx.axis_sizes)
                  for n in NAMES}
        sigs = {n: trace_mod.signature_multiset(tr.reduce)
                for n, tr in traces.items() if not tr.stateful}
        out[gname] = (traces, sigs)
    return out


@pytest.mark.parametrize("geometry", GEOMETRIES)
@pytest.mark.parametrize("name", NAMES)
def test_contract_rules_pass(name, geometry, traced):
    """Zero findings from the full analysis rule set."""
    traces, sigs = traced[geometry]
    actx = CONTEXTS[geometry]
    _, findings = check_strategy(get_strategy(name), actx.ctx,
                                 actx.axis_sizes, context_name=geometry,
                                 exact_reduce_sigs=sigs, tr=traces[name])
    assert not findings, [f.as_dict() for f in findings]


@pytest.mark.parametrize("geometry", GEOMETRIES)
@pytest.mark.parametrize("name", NAMES)
def test_wire_bytes_equal_auditor_extraction(name, geometry, traced):
    """Declared WireBytes == jaxpr-extracted bytes on BOTH tiers, and the
    outer tier is zero exactly when the geometry has one pod."""
    traces, _ = traced[geometry]
    actx = CONTEXTS[geometry]
    tr = traces[name]
    declared = get_strategy(name).bytes_per_device(actx.ctx)
    extracted = wire_total(tr.distribute + tr.reduce, actx.axis_sizes,
                           actx.ctx.outer_axes)
    assert (int(declared.inner), int(declared.outer)) == \
        (extracted.inner, extracted.outer), (name, geometry)
    if actx.ctx.outer_shards == 1:
        assert extracted.outer == 0
    else:
        assert extracted.outer > 0


@pytest.mark.parametrize("geometry", GEOMETRIES)
@pytest.mark.parametrize("name", NAMES)
def test_overflow_and_carry_structure(name, geometry, traced):
    """fwd["overflow"] is a scalar int32 everywhere; stateful strategies
    carry 1-D f32 state, return (grad, new_carry) with the aval
    preserved, and freeze the carry on the accumulate path."""
    traces, _ = traced[geometry]
    tr = traces[name]
    assert tr.fwd_overflow, (name, geometry)
    if tr.stateful:
        assert tr.carry_1d_f32, (name, geometry)
        assert tr.reduce_pair, (name, geometry)
        assert tr.carry_aval_preserved, (name, geometry)
        assert tr.carry_passthrough, (name, geometry)
    else:
        assert not tr.reduce_pair, (name, geometry)


# ---------------------------------------------------------------------------
# engine conformance: every strategy on the real host mesh
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", NAMES)
def test_dense_oracle_agreement_on_accumulate_path(name):
    """fit() (the accumulate path) matches the numpy GD oracle EXACTLY
    for every strategy — lossy ones must fall back to an exact reduce
    against the frozen carry, so no strategy earns a tolerance here."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution=name, max_hot=16)
    batches = list(_batches(128, 3))
    hot = hot_ids_from_corpus(cfg, batches, mesh)
    eng = DPMREngine(cfg, mesh, hot_ids=hot)
    eng.fit(lambda: iter(batches))
    f = dpmr.padded_features(cfg, mesh)
    oracle = _dense_lr_oracle(batches, f, cfg.learning_rate,
                              cfg.iterations)
    theta = np.asarray(eng.state.cold).copy()
    hids = np.asarray(eng.state.hot_ids)
    real = hids < 2**31 - 1
    theta[hids[real]] = np.asarray(eng.state.hot)[real]
    np.testing.assert_allclose(theta, oracle, atol=2e-4)
    # the frozen carry never accumulates residual through fit()
    assert float(jnp.abs(eng.state.strat).sum()) == 0.0


@pytest.mark.parametrize("name", NAMES)
def test_sgd_path_parity_with_a2a(name):
    """The carry-advancing SGD path: exact strategies reproduce a2a's
    parameters; lossy ones stay within their documented loss tolerance
    (error feedback keeps them convergent, not bit-identical)."""
    mesh = make_host_mesh(1, 1)
    batches = list(_batches(128, 6))
    ref = DPMREngine(_cfg(distribution="a2a"), mesh)
    ref_hist = ref.fit_sgd(iter(batches))
    eng = DPMREngine(_cfg(distribution=name), mesh)
    hist = eng.fit_sgd(iter(batches))
    if name in SGD_LOSS_RTOL:
        a, b = ref_hist[-1]["loss"], hist[-1]["loss"]
        assert abs(a - b) / a < SGD_LOSS_RTOL[name], (name, a, b)
    else:
        np.testing.assert_allclose(np.asarray(ref.state.cold),
                                   np.asarray(eng.state.cold), atol=1e-5)


@pytest.mark.parametrize("name", NAMES)
def test_overflow_metric_zero_at_default_capacity(name):
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(distribution=name), mesh)
    m = eng.train_step(sparse_corpus.make_batch(SPEC, 128, 0))
    assert m["overflow"] == 0, (name, m)


@pytest.mark.parametrize("name", NAMES)
def test_carry_init_and_elastic_reset(name):
    """DPMRState.strat is exactly the strategy's declared carry (or the
    (1,) placeholder), starts at zero, and elastic resharding returns it
    to zero while preserving parameters."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution=name)
    eng = DPMREngine(cfg, mesh)
    ctx = eng.step_fns(128).ctx
    carry = get_strategy(name).init_carry(ctx)
    want = (1,) if carry is None else tuple(carry.shape)
    assert tuple(eng.state.strat.shape) == want, (name, want)
    assert float(jnp.abs(eng.state.strat).sum()) == 0.0
    dirty = eng.state._replace(strat=jnp.ones_like(eng.state.strat))
    fresh = reshard_dpmr_state(dirty, cfg, mesh)
    assert float(jnp.abs(fresh.strat).max()) == 0.0, name
    np.testing.assert_array_equal(np.asarray(fresh.cold),
                                  np.asarray(dirty.cold))


@pytest.mark.parametrize("name", NAMES)
def test_save_restore_bitexact_continuation(name, tmp_path):
    """Interrupt-and-resume == uninterrupted, bit for bit, for EVERY
    strategy (carry included — dropping it would diverge the lossy
    ones)."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg(distribution=name)
    batches = list(_batches(128, 4))

    full = DPMREngine(cfg, mesh)
    full.fit_sgd(iter(batches))

    part = DPMREngine(cfg, mesh)
    part.fit_sgd(iter(batches[:2]))
    part.save(str(tmp_path))
    resumed = DPMREngine(cfg, mesh)
    resumed.restore(str(tmp_path))
    resumed.fit_sgd(iter(batches[2:]))
    for a, b in zip(full.state, resumed.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# multi-pod engine conformance for the compositions (slow, 8 devices)
# ---------------------------------------------------------------------------

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_compositions_on_pod_mesh():
    """On a real (2,2,2) (pod,data,model) mesh the registered
    compositions run hier_a2a on ICI and their lossy leg on DCN: fit()
    matches flat a2a exactly (accumulate fallback), fit_sgd banks a live
    carry of the composed length, and elastic reshard zeroes it."""
    body = """
import json
import jax.numpy as jnp, numpy as np
from repro import compat
from repro.api import DPMREngine, get_strategy
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.runtime.elastic import reshard_dpmr_state

src = get_source("zipf_sparse", batch_size=256, num_features=1<<12,
                 features_per_sample=16, signal_features=256, seed=0)
batches = list(src.iter_batches(limit=3))
base = dict(num_features=1<<12, max_features_per_sample=16, iterations=2,
            learning_rate=1.0, max_hot=32)
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
ref = DPMREngine(DPMRConfig(distribution="a2a", **base), mesh)
ref.fit(lambda: iter(batches))
for dist in ("hier_a2a+topk", "hier_a2a+int8"):
    # aggressive sparsification so the topk leg actually drops slots
    # (and banks a residual); fit() must match a2a exactly regardless
    cfg = DPMRConfig(distribution=dist, topk_frac=0.05, **base)
    eng = DPMREngine(cfg, mesh)
    eng.fit(lambda: iter(batches))
    assert eng.fns.ctx.outer_axes == ("pod",), eng.fns.ctx
    carry = get_strategy(dist).init_carry(eng.fns.ctx)
    assert carry is not None and carry.ndim == 1
    fit_diff = float(np.max(np.abs(np.asarray(ref.state.cold)
                                   - np.asarray(eng.state.cold))))
    hist = eng.fit_sgd(iter(batches))
    carry_mass = float(jnp.abs(eng.state.strat).sum())
    fresh = reshard_dpmr_state(eng.state, cfg, mesh)
    out[dist] = {
        "fit_diff": fit_diff,
        "carry_len": int(carry.shape[0]),
        "strat_len": int(eng.state.strat.shape[0]),
        "carry_mass": carry_mass,
        "reset_mass": float(jnp.abs(fresh.strat).max()),
        "final_loss": hist[-1]["loss"],
    }
print(json.dumps(out))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for dist, r in out.items():
        assert r["fit_diff"] < 1e-6, (dist, r)
        # the global strat vector stacks one per-device carry per shard
        assert r["strat_len"] == 8 * r["carry_len"], (dist, r)
        assert r["carry_mass"] > 0.0, (dist, r)
        assert r["reset_mass"] == 0.0, (dist, r)
        assert np.isfinite(r["final_loss"]), (dist, r)
