"""Serving subsystem lifecycle tests (`repro.serve`).

Covers the acceptance claims of the serving tentpole: coalescing
correctness (concurrent requests answer exactly what per-request
`engine.predict` would), `predict_padded` bucket parity (the recompile-trap
fix), deadline flush on a partial batch, clean queue drain on shutdown,
restore-into-serving round-trip, and a `slow` 8-emulated-device run.
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.api import DPMREngine
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.serve import (BatchingConfig, DPMRServeEngine, HotCacheConfig,
                         MicroBatcher, ServeMetrics)

F = 1 << 10
K = 8


@pytest.fixture(scope="module")
def engine():
    """One trained engine shared by the read-only serving tests (tests
    that train further build their own)."""
    mesh = make_host_mesh(1, 1)
    cfg = DPMRConfig(num_features=F, max_features_per_sample=K, max_hot=16)
    eng = DPMREngine(cfg, mesh)
    eng.fit_sgd(_source().iter_batches(), steps=8)
    return eng


def _source(batch_size=4, num_batches=16, seed=0):
    return get_source("zipf_sparse", batch_size=batch_size,
                      num_batches=num_batches, num_features=F,
                      features_per_sample=K, seed=seed)


def _req(src, i):
    b = src.batch(i)
    return b["ids"], b["vals"]


# ---------------------------------------------------------------------------
# predict_padded: the recompile-trap fix
# ---------------------------------------------------------------------------


def test_predict_padded_bit_identical(engine):
    src = _source(batch_size=5)
    for n in (1, 2, 3, 5):
        b = src.batch(0)
        ids, vals = b["ids"][:n], b["vals"][:n]
        padded = engine.predict_padded({"ids": ids, "vals": vals})
        plain = engine.predict({"ids": ids, "vals": vals})
        np.testing.assert_array_equal(padded, plain)   # bit-exact


def test_predict_padded_reuses_bucketed_compilations(engine):
    before = set(engine._fns)
    src = _source(batch_size=8)
    b = src.batch(0)
    for n in (5, 6, 7, 8):                  # all bucket to 8
        engine.predict_padded({"ids": b["ids"][:n], "vals": b["vals"][:n]})
    new = set(engine._fns) - before
    assert new <= {8}, f"sizes 5..8 must share the 8-row entry, got {new}"


def test_bucket_for_default_ladder(engine):
    assert [engine.bucket_for(n) for n in (1, 2, 3, 4, 5, 9)] == \
        [1, 2, 4, 4, 8, 16]


def test_bucket_for_explicit_and_errors(engine):
    assert engine.bucket_for(3, buckets=(4, 16)) == 4
    assert engine.bucket_for(5, buckets=(4, 16)) == 16
    with pytest.raises(ValueError, match="largest bucket"):
        engine.bucket_for(17, buckets=(4, 16))
    with pytest.raises(ValueError, match="positive"):
        engine.bucket_for(0)


# ---------------------------------------------------------------------------
# coalescing correctness
# ---------------------------------------------------------------------------


def test_concurrent_requests_match_sequential_predict(engine):
    """K client threads through the coalescer == per-request predict."""
    src = _source(num_batches=12, seed=1)
    reqs = [_req(src, i) for i in range(12)]
    results: list = [None] * len(reqs)
    srv = DPMRServeEngine(engine,
                          batching=BatchingConfig(max_batch=16,
                                                  max_wait_ms=5.0),
                          hot_cache=None)     # pure batcher path

    def client(lo, hi):
        for i in range(lo, hi):
            results[i] = srv.submit(*reqs[i])

    threads = [threading.Thread(target=client, args=(c * 4, c * 4 + 4))
               for c in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    got = [np.asarray(f.result(timeout=120)) for f in results]
    srv.stop()
    for (ids, vals), g in zip(reqs, got, strict=True):
        np.testing.assert_array_equal(
            g, engine.predict({"ids": ids, "vals": vals}))
    m = srv.metrics_snapshot()
    assert m["requests"] == 12 and m["flushes"] >= 1


def test_mixed_request_sizes_share_buckets(engine):
    """Mixed sizes stay bit-correct AND don't compile one entry per size."""
    srv = DPMRServeEngine(engine, batching=BatchingConfig(max_batch=8,
                                                          max_wait_ms=1.0),
                          hot_cache=None)
    src = _source(batch_size=5, seed=2)
    sizes = [1, 2, 3, 4, 5, 1, 3, 5]
    futs = []
    for i, n in enumerate(sizes):
        b = src.batch(i)
        futs.append(srv.submit(b["ids"][:n], b["vals"][:n]))
    got = [np.asarray(f.result(timeout=120)) for f in futs]
    srv.stop()
    for i, (n, g) in enumerate(zip(sizes, got, strict=True)):
        b = src.batch(i)
        np.testing.assert_array_equal(
            g, engine.predict_padded({"ids": b["ids"][:n],
                                      "vals": b["vals"][:n]}))
    # every flush padded to the power-of-two ladder {1,2,4,8}
    assert all(s in (1, 2, 4, 8) for s in srv.metrics._flush_padded)


def test_hot_cache_hits_inside_serve_engine(engine):
    """End-to-end: a Zipf-head request short-circuits the queue and still
    answers bit-identically."""
    srv = DPMRServeEngine(
        engine, batching=BatchingConfig(max_batch=8, max_wait_ms=1.0),
        hot_cache=HotCacheConfig(max_hot=64, threshold=0.0, window=64,
                                 refresh_every=1000))
    src = _source(seed=3)
    ids, vals = _req(src, 0)
    first = np.asarray(srv.submit(ids, vals).result(timeout=120))
    again = np.asarray(srv.submit(ids, vals).result(timeout=120))
    srv.stop()
    m = srv.metrics_snapshot()
    assert m["cache_hits"] >= 1, m
    np.testing.assert_array_equal(first, again)
    np.testing.assert_array_equal(
        first, engine.predict({"ids": ids, "vals": vals}))


# ---------------------------------------------------------------------------
# lifecycle: deadline, drain, stop
# ---------------------------------------------------------------------------


def test_deadline_flush_fires_on_partial_batch(engine):
    srv = DPMRServeEngine(engine,
                          batching=BatchingConfig(max_batch=512,
                                                  max_wait_ms=30.0),
                          hot_cache=None)
    src = _source(seed=4)
    ids, vals = _req(src, 0)
    probs = srv.submit(ids, vals).result(timeout=120)   # alone in the queue
    assert probs.shape == (4,)
    m = srv.metrics_snapshot()
    srv.stop()
    assert m["flush_deadline"] == 1 and m.get("flush_full", 0) == 0
    assert m["batch_mean"] == 4.0       # partial: far below max_batch


def test_full_flush_fires_on_max_batch(engine):
    srv = DPMRServeEngine(engine,
                          batching=BatchingConfig(max_batch=8,
                                                  max_wait_ms=10_000.0),
                          hot_cache=None)
    src = _source(seed=5)
    futs = [srv.submit(*_req(src, i)) for i in range(2)]   # 8 rows == full
    for f in futs:
        f.result(timeout=120)           # resolves long before the window
    m = srv.metrics_snapshot()
    srv.stop()
    assert m["flush_full"] >= 1


def test_stop_drains_pending_requests(engine):
    """Queued requests are answered on shutdown, not dropped."""
    srv = DPMRServeEngine(engine,
                          batching=BatchingConfig(max_batch=1024,
                                                  max_wait_ms=60_000.0),
                          hot_cache=None)
    src = _source(seed=6)
    reqs = [_req(src, i) for i in range(3)]
    futs = [srv.submit(*r) for r in reqs]
    srv.stop()                          # drain: nobody waits out the hour
    for (ids, vals), f in zip(reqs, futs, strict=True):
        assert f.done()
        np.testing.assert_array_equal(
            np.asarray(f.result()),
            engine.predict({"ids": ids, "vals": vals}))
    assert srv.metrics_snapshot()["flush_drain"] >= 1


def test_submit_after_stop_raises(engine):
    srv = DPMRServeEngine(engine, hot_cache=None)
    srv.stop()
    src = _source(seed=7)
    with pytest.raises(RuntimeError, match="stopped"):
        srv.submit(*_req(src, 0))


def test_stop_is_idempotent_and_restartable(engine):
    srv = DPMRServeEngine(engine, hot_cache=None)
    srv.stop()
    srv.stop()
    srv.start()                          # state stayed resident
    src = _source(seed=8)
    ids, vals = _req(src, 0)
    np.testing.assert_array_equal(
        np.asarray(srv.submit(ids, vals).result(timeout=120)),
        engine.predict({"ids": ids, "vals": vals}))
    srv.stop()


def test_predict_fn_exception_fails_futures_not_queue():
    calls = []

    def boom(ids, vals):
        calls.append(len(ids))
        raise RuntimeError("kaboom")

    with MicroBatcher(boom, BatchingConfig(max_batch=4, max_wait_ms=1.0),
                      ServeMetrics()) as mb:
        f1 = mb.submit(np.zeros((1, 4), np.int32), np.zeros((1, 4)))
        with pytest.raises(RuntimeError, match="kaboom"):
            f1.result(timeout=60)
        # the queue survives a failing batch: the next request still flushes
        f2 = mb.submit(np.zeros((2, 4), np.int32), np.zeros((2, 4)))
        with pytest.raises(RuntimeError, match="kaboom"):
            f2.result(timeout=60)
    assert calls == [1, 2]


def test_request_validation(engine):
    srv = DPMRServeEngine(engine, hot_cache=None)
    src = _source(seed=9)
    ids, vals = _req(src, 0)
    # 1-D single-sample requests are promoted to (1, K)
    one = np.asarray(srv.submit(ids[0], vals[0]).result(timeout=120))
    assert one.shape == (1,)
    # short rows pad to the engine's K
    short = np.asarray(
        srv.submit(ids[:1, :3], vals[:1, :3]).result(timeout=120))
    wide_ids = np.concatenate([ids[:1, :3],
                               np.full((1, K - 3), -1, np.int32)], axis=1)
    wide_vals = np.concatenate([vals[:1, :3], np.zeros((1, K - 3))], axis=1)
    np.testing.assert_array_equal(
        short, engine.predict({"ids": wide_ids, "vals": wide_vals}))
    with pytest.raises(ValueError, match="max_features_per_sample"):
        srv.submit(np.zeros((1, K + 1), np.int32), np.zeros((1, K + 1)))
    with pytest.raises(ValueError, match="one shape"):
        srv.submit(ids[:2], vals[:1])
    srv.stop()


# ---------------------------------------------------------------------------
# restore-into-serving
# ---------------------------------------------------------------------------


def test_restore_into_serving_roundtrip(tmp_path):
    mesh = make_host_mesh(1, 1)
    cfg = DPMRConfig(num_features=F, max_features_per_sample=K, max_hot=16)
    live = DPMREngine(cfg, mesh)
    live.fit_sgd(_source(seed=10).iter_batches(), steps=6)
    live.save(str(tmp_path))

    srv = DPMRServeEngine.from_checkpoint(
        cfg, mesh, str(tmp_path),
        batching=BatchingConfig(max_batch=8, max_wait_ms=1.0))
    assert int(srv.engine.state.step) == 6
    src = _source(seed=11)
    for i in range(3):
        ids, vals = _req(src, i)
        np.testing.assert_array_equal(
            np.asarray(srv.submit(ids, vals).result(timeout=120)),
            live.predict({"ids": ids, "vals": vals}))
    srv.stop()


def test_from_checkpoint_rejects_dense(tmp_path):
    mesh = make_host_mesh(1, 1)
    cfg = DPMRConfig(num_features=F, max_features_per_sample=K)
    Checkpointer(str(tmp_path)).save(
        0, {"params": np.zeros(3, np.float32)}, extra={"kind": "lm_dense"})
    with pytest.raises(ValueError, match="not a sparse DPMR checkpoint"):
        DPMRServeEngine.from_checkpoint(cfg, mesh, str(tmp_path))


def test_from_checkpoint_empty_dir_raises(tmp_path):
    mesh = make_host_mesh(1, 1)
    cfg = DPMRConfig(num_features=F, max_features_per_sample=K)
    with pytest.raises(FileNotFoundError):
        DPMRServeEngine.from_checkpoint(cfg, mesh, str(tmp_path))


# ---------------------------------------------------------------------------
# 8 emulated devices (nightly)
# ---------------------------------------------------------------------------

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_serving_8dev_parity():
    """Full serve stack on an 8-device pod mesh: coalesced, bucket-padded
    micro-batches answer bit-identically to predict_padded per request."""
    body = """
import json
import numpy as np
from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.serve import BatchingConfig, DPMRServeEngine, HotCacheConfig

mesh = make_host_mesh(4, 2)
cfg = DPMRConfig(num_features=1 << 12, max_features_per_sample=8,
                 max_hot=16)
src = get_source("zipf_sparse", batch_size=16, num_batches=8,
                 num_features=1 << 12, features_per_sample=8, seed=0)
eng = DPMREngine(cfg, mesh)
eng.fit_sgd(src.iter_batches(), steps=8)
srv = DPMRServeEngine(
    eng, batching=BatchingConfig(max_batch=32, max_wait_ms=5.0),
    hot_cache=HotCacheConfig(max_hot=64, threshold=0.0, window=64,
                             refresh_every=1000))
reqs = [(src.batch(i)["ids"][:n], src.batch(i)["vals"][:n])
        for i, n in enumerate([16, 3, 8, 11, 1, 16])]
futs = [srv.submit(ids, vals) for ids, vals in reqs]
got = [np.asarray(f.result(timeout=300)) for f in futs]
srv.stop()
ok = all(
    np.array_equal(g, eng.predict_padded({"ids": ids, "vals": vals}))
    for g, (ids, vals) in zip(got, reqs))
m = srv.metrics_snapshot()
print(json.dumps({"ok": bool(ok), "flushes": m["flushes"],
                  "requests": m["requests"],
                  "compiled": m["compiled_step_fns"]}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"], out
    assert out["requests"] == 6
