"""Checkpoint manager + fault tolerance: atomicity, keep-N, resume
determinism, failure-injected restart, elastic restore."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpointer import Checkpointer
from repro.launch.train import build_parser, train_loop
from repro.runtime.fault_tolerance import (FailureInjector, PreemptionGuard,
                                           StragglerWatchdog,
                                           run_with_restarts)


def _state(x=1.0):
    return {"a": jnp.full((4, 4), x), "b": {"c": jnp.arange(3)},
            "step": jnp.int32(0)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    s = _state(3.5)
    ck.save(10, s, extra={"data_step": 10})
    restored, manifest = ck.restore(_state(0.0))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(s["a"]))
    assert manifest["extra"]["data_step"] == 10


def test_keep_n_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, _state(step))
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(5, _state(5.0), block=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_atomic_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, _state())
    for name in os.listdir(tmp_path):
        assert not name.endswith(".tmp")


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state())


def _args(tmp, steps, save_every=5):
    return build_parser().parse_args([
        "--arch", "yi-6b", "--smoke", "--steps", str(steps), "--batch", "4",
        "--seq", "16", "--ckpt", str(tmp), "--save-every", str(save_every),
        "--log-every", "0"])


def test_resume_is_deterministic(tmp_path):
    """Straight 16-step run == 8 steps + crash + resume (same final loss)."""
    a = str(tmp_path / "a")
    out1 = train_loop(_args(a, 16, save_every=100))

    b = str(tmp_path / "b")
    args_b = _args(b, 8, save_every=8)
    train_loop(args_b)
    args_b2 = _args(b, 16, save_every=100)
    out2 = train_loop(args_b2)
    np.testing.assert_allclose(out1["losses"][-1], out2["losses"][-1],
                               rtol=1e-4)


def test_injected_failure_recovery(tmp_path):
    inj = FailureInjector(fail_at_steps=[6])
    args = _args(str(tmp_path), 12, save_every=3)

    def loop(_):
        return train_loop(args, fail_injector=inj)["last_step"]

    last = run_with_restarts(loop, max_restarts=2)
    assert last == 12
    assert inj.failed == [6]


def test_preemption_guard_triggers_save(tmp_path):
    guard = PreemptionGuard(signals=())
    guard.trigger()
    assert guard.preempted()


def test_straggler_watchdog_flags_outlier():
    import time

    wd = StragglerWatchdog(window=10, factor=2.0)
    for i in range(6):
        wd.step_start()
        time.sleep(0.01)
        wd.step_end(i)
    wd.step_start()
    time.sleep(0.15)
    wd.step_end(99)
    assert wd.events and wd.events[-1]["step"] == 99


def test_elastic_restore_under_new_sharding(tmp_path):
    """Save replicated, restore sharded (mesh change) — values identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    ck = Checkpointer(str(tmp_path))
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, s)
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ck.restore(s, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))
    assert restored["w"].sharding == sh["w"]
