"""Checkpoint manager + fault tolerance: atomicity, keep-N, resume
determinism, failure-injected restart, elastic restore."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpointer import Checkpointer
from repro.launch.train import build_parser, train_loop
from repro.runtime.fault_tolerance import (FailureInjector, PreemptionGuard,
                                           StragglerWatchdog,
                                           run_with_restarts)


def _state(x=1.0):
    return {"a": jnp.full((4, 4), x), "b": {"c": jnp.arange(3)},
            "step": jnp.int32(0)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    s = _state(3.5)
    ck.save(10, s, extra={"data_step": 10})
    restored, manifest = ck.restore(_state(0.0))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(s["a"]))
    assert manifest["extra"]["data_step"] == 10


def test_keep_n_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, _state(step))
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(5, _state(5.0), block=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_atomic_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, _state())
    for name in os.listdir(tmp_path):
        assert not name.endswith(".tmp")


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state())


def test_truncated_manifest_falls_back_to_previous(tmp_path):
    """The crash-consistency contract: a checkpoint whose manifest was cut
    off mid-write (simulated partial write/crash) is INVISIBLE — discovery
    skips it and restore hands back the newest complete step instead of
    crashing on the bad one."""
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, _state(1.0))
    ck.save(2, _state(2.0))
    manifest = tmp_path / "step_0000000002" / "manifest.json"
    raw = manifest.read_bytes()
    manifest.write_bytes(raw[: len(raw) // 2])       # truncate mid-write
    assert ck.all_steps() == [1]
    assert ck.latest_step() == 1
    restored, man = ck.restore(_state(0.0))
    assert man["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(_state(1.0)["a"]))
    # a missing manifest (killed before the in-dir rename) hides the same way
    manifest.unlink()
    assert ck.all_steps() == [1]


def test_async_save_bit_exact_vs_sync(tmp_path):
    """`block=False` must produce byte-identical array files and an
    equivalent manifest to the synchronous path at the same step."""
    s = _state(7.25)
    sync, asyn = Checkpointer(str(tmp_path / "s")), \
        Checkpointer(str(tmp_path / "a"))
    sync.save(3, s, extra={"k": 1}, block=True)
    asyn.save(3, s, extra={"k": 1}, block=False)
    asyn.wait()
    d_s, d_a = (tmp_path / m / "step_0000000003" for m in ("s", "a"))
    names = sorted(p.name for p in d_s.iterdir())
    assert names == sorted(p.name for p in d_a.iterdir())
    for name in names:
        if name == "manifest.json":
            import json

            ms = json.loads((d_s / name).read_text())
            ma = json.loads((d_a / name).read_text())
            ms.pop("time"), ma.pop("time")
            assert ms == ma
        else:
            assert (d_s / name).read_bytes() == (d_a / name).read_bytes()


def test_async_snapshot_isolation_under_donation(tmp_path):
    """An async save captures the PRE-step state even though the training
    loop immediately keeps going and the jitted step DONATES (mutates in
    place) the very buffers that were live at save time — the device->host
    snapshot happens inside save(), before it returns."""
    from repro.api import DPMREngine
    from repro.configs.base import DPMRConfig
    from repro.data import get_source
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8)
    src = get_source("zipf_sparse", batch_size=32, num_batches=16,
                     num_features=1 << 10, features_per_sample=8, seed=0)
    eng = DPMREngine(cfg, mesh)
    eng.fit_sgd(src, steps=2)
    snap = np.asarray(eng.state.cold).copy()
    step_saved = eng.save(str(tmp_path), block=False)
    eng.fit_sgd(src, steps=3)               # donates/overwrites live state
    eng.wait_saves()
    fresh = DPMREngine(cfg, make_host_mesh(1, 1))
    manifest = fresh.restore(str(tmp_path))
    assert manifest["step"] == step_saved == 2
    np.testing.assert_array_equal(np.asarray(fresh.state.cold), snap)
    assert not np.array_equal(np.asarray(eng.state.cold), snap)


def _args(tmp, steps, save_every=5):
    return build_parser().parse_args([
        "--arch", "yi-6b", "--smoke", "--steps", str(steps), "--batch", "4",
        "--seq", "16", "--ckpt", str(tmp), "--save-every", str(save_every),
        "--log-every", "0"])


def test_resume_is_deterministic(tmp_path):
    """Straight 16-step run == 8 steps + crash + resume (same final loss)."""
    a = str(tmp_path / "a")
    out1 = train_loop(_args(a, 16, save_every=100))

    b = str(tmp_path / "b")
    args_b = _args(b, 8, save_every=8)
    train_loop(args_b)
    args_b2 = _args(b, 16, save_every=100)
    out2 = train_loop(args_b2)
    np.testing.assert_allclose(out1["losses"][-1], out2["losses"][-1],
                               rtol=1e-4)


def test_injected_failure_recovery(tmp_path):
    inj = FailureInjector(fail_at_steps=[6])
    args = _args(str(tmp_path), 12, save_every=3)

    def loop(_):
        return train_loop(args, fail_injector=inj)["last_step"]

    last = run_with_restarts(loop, max_restarts=2)
    assert last == 12
    assert inj.failed == [6]


def test_preemption_guard_triggers_save(tmp_path):
    guard = PreemptionGuard(signals=())
    guard.trigger()
    assert guard.preempted()


def test_straggler_watchdog_flags_outlier():
    import time

    wd = StragglerWatchdog(window=10, factor=2.0)
    for i in range(6):
        wd.step_start()
        time.sleep(0.01)
        wd.step_end(i)
    wd.step_start()
    time.sleep(0.15)
    wd.step_end(99)
    assert wd.events and wd.events[-1]["step"] == 99


def test_elastic_restore_under_new_sharding(tmp_path):
    """Save replicated, restore sharded (mesh change) — values identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    ck = Checkpointer(str(tmp_path))
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, s)
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ck.restore(s, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))
    assert restored["w"].sharding == sh["w"]
