"""Multi-device semantics, run in subprocesses with 8 fake host devices
(XLA_FLAGS can't change after jax initializes in the main pytest process).

Covers: DP/TP/FSDP mesh-layout invariance of training, DPMR sparse-face
multi-shard == single-shard, the explicit DPMR-dense (FSDP) linear vs plain
matmul, and cross-pod compressed training.
"""
import json
import os
import subprocess
import sys

import pytest

# every test here re-inits jax in a subprocess with 8 fake devices — minutes
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


COMMON = """
import json
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.launch.mesh import make_host_mesh
"""


def test_training_invariant_to_mesh_layout():
    """Same model, same data: loss identical on (1,1), (4,2), (2,4)."""
    out = run_py(COMMON + """
from repro.models import registry
from repro.train import trainer
from repro.configs.base import TrainConfig, ParallelConfig
from repro.data.pipeline import LMDataset, LMDataConfig

cfg = registry.smoke_config("granite-8b")
spec = registry.get_spec("granite-8b")
tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=10)
losses = {}
for (d, m) in [(1,1),(4,2),(2,4)]:
    mesh = make_host_mesh(d, m)
    pc = ParallelConfig(microbatches=2)
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        ds = LMDataset(LMDataConfig(cfg.vocab_size, 16, 8))
        for i in range(4):
            state, met = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
    losses[f"{d}x{m}"] = float(met["loss"])
print(json.dumps(losses))
""")
    vals = list(out.values())
    assert max(vals) - min(vals) < 2e-3, out


def test_dpmr_multi_shard_matches_single():
    out = run_py(COMMON + """
from repro.api import DPMREngine, hot_ids_from_corpus
from repro.configs.base import DPMRConfig
from repro.data import get_source

src = get_source("zipf_sparse", batch_size=256, num_features=1<<12,
                 features_per_sample=16, signal_features=256, seed=0)
cfg = DPMRConfig(num_features=1<<12, max_features_per_sample=16,
                 iterations=2, learning_rate=1.0, max_hot=32)
batches = list(src.iter_batches(limit=4))
colds = {}
for (d, m) in [(1,1),(4,2)]:
    mesh = make_host_mesh(d, m)
    hot = hot_ids_from_corpus(cfg, batches, mesh)
    eng = DPMREngine(cfg, mesh, hot_ids=hot)
    eng.fit(lambda: iter(batches))
    colds[f"{d}x{m}"] = np.asarray(eng.state.cold)
diff = float(np.max(np.abs(colds["1x1"] - colds["4x2"])))
print(json.dumps({"max_diff": diff}))
""")
    assert out["max_diff"] < 1e-6, out


def test_hier_and_compressed_strategies_on_pod_mesh():
    """(2,2,2) (pod,data,model) mesh: hier_a2a's two-level exchange
    produces the same parameters as flat a2a (float-order tolerance), and
    compressed_reduce trains with a live error-feedback carry."""
    out = run_py(COMMON + """
from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import get_source

src = get_source("zipf_sparse", batch_size=256, num_features=1<<12,
                 features_per_sample=16, signal_features=256, seed=0)
batches = list(src.iter_batches(limit=3))
base = dict(num_features=1<<12, max_features_per_sample=16, iterations=2,
            learning_rate=1.0, max_hot=32)
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
colds = {}
for dist in ("a2a", "hier_a2a"):
    eng = DPMREngine(DPMRConfig(distribution=dist, **base), mesh)
    eng.fit(lambda: iter(batches))
    assert eng.fns.ctx.outer_axes == ("pod",), eng.fns.ctx
    colds[dist] = np.asarray(eng.state.cold)
sgd = {}
hist = None
for dist in ("a2a", "compressed_reduce"):
    eng = DPMREngine(DPMRConfig(distribution=dist, **base), mesh)
    hist = eng.fit_sgd(iter(batches))
    sgd[dist] = eng
print(json.dumps({
    "max_diff": float(np.max(np.abs(colds["a2a"] - colds["hier_a2a"]))),
    "comp_final_loss": hist[-1]["loss"],
    "comp_vs_a2a": float(np.max(np.abs(
        np.asarray(sgd["compressed_reduce"].state.cold)
        - np.asarray(sgd["a2a"].state.cold)))),
    "carry_nonzero": bool(np.abs(np.asarray(
        sgd["compressed_reduce"].state.strat)).sum() > 0)}))
""")
    assert out["max_diff"] < 1e-5, out          # exact up to float order
    import math
    assert math.isfinite(out["comp_final_loss"]), out
    assert out["carry_nonzero"] is True, out
    assert out["comp_vs_a2a"] < 0.05, out       # quantized but tracking


def test_overlap_and_topk_strategies_on_pod_mesh():
    """(2,2,2) (pod,data,model) mesh: overlap_a2a's micro-chunked exchange
    is BIT-IDENTICAL to flat a2a (same losses, same parameters — no
    float-order tolerance: element routing is unchanged, only the
    collective schedule differs), and topk_reduce at a sparsifying
    fraction trains with a live error-feedback residual that tracks a2a."""
    out = run_py(COMMON + """
from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import get_source

src = get_source("zipf_sparse", batch_size=256, num_features=1<<12,
                 features_per_sample=16, signal_features=256, seed=0)
batches = list(src.iter_batches(limit=3))
base = dict(num_features=1<<12, max_features_per_sample=16, iterations=2,
            learning_rate=1.0, max_hot=32)
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
state = {}
for dist in ("a2a", "overlap_a2a"):
    eng = DPMREngine(DPMRConfig(distribution=dist, **base), mesh)
    hist = eng.fit_sgd(iter(batches))
    out[f"losses_{dist}"] = [h["loss"] for h in hist]
    state[dist] = eng
topk = DPMREngine(DPMRConfig(distribution="topk_reduce", topk_frac=0.05,
                             **base), mesh)
topk.fit_sgd(iter(batches))
a = np.asarray(state["a2a"].state.cold)
print(json.dumps({
    "overlap_bit_identical": bool(np.array_equal(
        a, np.asarray(state["overlap_a2a"].state.cold))),
    "losses_equal": out["losses_a2a"] == out["losses_overlap_a2a"],
    "topk_carry_nonzero": bool(np.abs(np.asarray(
        topk.state.strat)).sum() > 0),
    "topk_vs_a2a": float(np.max(np.abs(
        a - np.asarray(topk.state.cold))))}))
""")
    assert out["overlap_bit_identical"] is True, out
    assert out["losses_equal"] is True, out
    assert out["topk_carry_nonzero"] is True, out
    assert out["topk_vs_a2a"] < 0.05, out       # sparsified but tracking


def test_explicit_fsdp_linear_matches_matmul():
    """core.fsdp.dpmr_dense_linear (all_gather/psum_scatter staging) ==
    plain x @ W, forward AND backward."""
    out = run_py(COMMON + """
from jax.sharding import PartitionSpec as P
from repro.core.fsdp import dpmr_dense_linear

mesh = make_host_mesh(8, 1)
rng = np.random.default_rng(0)
D, F, B = 32, 24, 16
w = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

def staged(w, x):
    f = compat.shard_map(lambda ws, xs: dpmr_dense_linear(ws, xs, "data"),
                         mesh=mesh, in_specs=(P("data", None), P()),
                         out_specs=P(), check_vma=False)
    return f(w, x)

def loss_staged(w, x): return jnp.sum(jnp.sin(staged(w, x)))
def loss_plain(w, x): return jnp.sum(jnp.sin(x @ w))

with compat.set_mesh(mesh):
    y1 = staged(w, x)
    g1 = jax.grad(loss_staged)(w, x)
y2 = x @ w
g2 = jax.grad(loss_plain)(w, x)
print(json.dumps({
  "fwd": float(jnp.max(jnp.abs(y1 - y2))),
  "bwd": float(jnp.max(jnp.abs(g1 - g2)))}))
""")
    assert out["fwd"] < 1e-4 and out["bwd"] < 1e-4, out


@pytest.mark.xfail(
    tuple(int(x) for x in __import__("jax").__version__.split(".")[:2])
    < (0, 5),
    reason="old-jax partial-auto shard_map rejects sharding constraints "
           "naming the manual 'pod' axis (transformer._constrain inside "
           "the pod-manual region); fixed in newer jax",
    strict=False)
def test_cross_pod_compressed_training_converges():
    """Compressed cross-pod grads: loss tracks uncompressed within 5%."""
    out = run_py(COMMON + """
from repro.models import registry
from repro.train import trainer
from repro.configs.base import TrainConfig, ParallelConfig
from repro.data.pipeline import LMDataset, LMDataConfig

cfg = registry.smoke_config("yi-6b")
spec = registry.get_spec("yi-6b")
tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=20)

def run(compress):
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    pc = ParallelConfig(compress_pod_grads=compress)
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        ds = LMDataset(LMDataConfig(cfg.vocab_size, 16, 8))
        for i in range(12):
            state, m = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
    return float(m["loss"])

print(json.dumps({"plain": run(False), "compressed": run(True)}))
""")
    assert abs(out["plain"] - out["compressed"]) / out["plain"] < 0.05, out


def test_context_parallel_attention_matches_blocked():
    """CP attention (q sequence-sharded, kv-only gather) == blocked oracle,
    forward and gradient, on a sharded mesh."""
    out = run_py(COMMON + """
from repro.models import layers
mesh = make_host_mesh(2, 4)
rng = np.random.default_rng(0)
b, s, h, kh, d = 2, 64, 4, 2, 16
q = jnp.asarray(rng.normal(size=(b,s,h,d)), jnp.float32)
k = jnp.asarray(rng.normal(size=(b,s,kh,d)), jnp.float32)
v = jnp.asarray(rng.normal(size=(b,s,kh,d)), jnp.float32)
res = {}
with compat.set_mesh(mesh):
    for causal, window in [(True,0),(True,16),(False,0)]:
        cp = jax.jit(lambda q,k,v: layers.context_parallel_attention(
            q,k,v,causal=causal,window=window,kv_block=16))(q,k,v)
        ref = layers.blocked_causal_attention(
            q,k,v,window=window,q_block=16,kv_block=16) if causal \\
            else layers._bidirectional_blocked(q,k,v,q_block=16,kv_block=16)
        res[f"{causal}_{window}"] = float(jnp.max(jnp.abs(cp-ref)))
    g = jax.jit(jax.grad(lambda q,k,v: jnp.sum(jnp.sin(
        layers.context_parallel_attention(q,k,v)))))(q,k,v)
    res["grad_finite"] = bool(jnp.all(jnp.isfinite(g)))
print(json.dumps(res))
""")
    assert out.pop("grad_finite") is True
    assert all(v < 1e-5 for v in out.values()), out


def test_cp_train_step_matches_auto():
    """Training with attn_mode=cp computes the same loss as attn_mode=auto."""
    out = run_py(COMMON + """
from repro.models import registry
from repro.train import trainer
from repro.configs.base import TrainConfig, ParallelConfig
from repro.data.pipeline import LMDataset, LMDataConfig

cfg = registry.smoke_config("granite-8b")
spec = registry.get_spec("granite-8b")
tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=10)
res = {}
for mode in ("auto", "cp"):
    mesh = make_host_mesh(2, 4)
    pc = ParallelConfig(attn_mode=mode)
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        ds = LMDataset(LMDataConfig(cfg.vocab_size, 16, 8))
        for i in range(3):
            state, m = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
    res[mode] = float(m["loss"])
print(json.dumps(res))
""")
    assert abs(out["auto"] - out["cp"]) < 2e-3, out


def test_multipod_mesh_trains():
    """(2,2,2) pod mesh: one train step on every family that fits."""
    out = run_py(COMMON + """
from repro.models import registry
from repro.train import trainer
from repro.configs.base import TrainConfig, ParallelConfig
from repro.data.pipeline import LMDataset, LMDataConfig, encdec_batch

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
res = {}
for arch in ["granite-8b", "mixtral-8x22b", "zamba2-2.7b", "whisper-small"]:
    cfg = registry.smoke_config(arch)
    spec = registry.get_spec(arch)
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=5)
    pc = ParallelConfig()
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        ds = LMDataset(LMDataConfig(cfg.vocab_size, 16, 8))
        b = ds.batch(0)
        if cfg.family == "encdec":
            b = encdec_batch(ds, 0, cfg.d_model)
        state, m = step(state, jax.tree.map(jnp.asarray, b))
    res[arch] = float(m["loss"])
print(json.dumps(res))
""", timeout=900)
    import math
    assert all(math.isfinite(v) for v in out.values()), out
