"""Auditor tests: the jaxpr-level wire-model & contract checks of
`repro.analysis` — every registered strategy must pass on 1-device,
8-device single-pod, and (2, 4) multi-pod analytic contexts; deliberately
miswired strategies must be rejected; and the extracted collective
signatures are pinned per strategy so future wire drift is caught even if
someone edits the declared model and the extractor in lockstep."""
import jax.numpy as jnp
import pytest

from repro.analysis import (
    audit_registry,
    build_contexts,
    check_strategy,
    collective_wire,
    trace_strategy,
    wire_total,
)
from repro.analysis.audit import AuditContext
from repro.analysis.trace import Collective
from repro.api.strategies import (
    _REGISTRY,
    AllToAllStrategy,
    TopKReduceStrategy,
    WireBytes,
    get_strategy,
    list_strategies,
    register_strategy,
)

STRATEGIES = ("a2a", "allgather", "psum_scatter", "hier_a2a",
              "compressed_reduce", "topk_reduce", "overlap_a2a")
CONTEXTS = {a.name: a for a in build_contexts(production=False)}


def _check(name: str, actx: AuditContext):
    strat = get_strategy(name)
    exact_sigs = {}
    for n in STRATEGIES:
        tr = trace_strategy(get_strategy(n), actx.ctx, actx.axis_sizes)
        if not tr.stateful:
            from repro.analysis.trace import signature_multiset
            exact_sigs[n] = signature_multiset(tr.reduce)
    return check_strategy(strat, actx.ctx, actx.axis_sizes,
                          context_name=actx.name,
                          exact_reduce_sigs=exact_sigs)


@pytest.mark.parametrize("ctx_name", ["1dev", "pod8", "multipod"])
@pytest.mark.parametrize("name", STRATEGIES)
def test_registered_strategies_pass_audit(name, ctx_name):
    """Every built-in passes every rule on every analytic geometry."""
    tr, findings = _check(name, CONTEXTS[ctx_name])
    assert tr is not None
    assert findings == [], findings


@pytest.mark.parametrize("name", STRATEGIES)
def test_declared_wire_matches_extracted(name):
    """The declared WireBytes equals the jaxpr-extracted bytes on both
    tiers — the audit's central cross-check, asserted directly."""
    actx = CONTEXTS["multipod"]
    tr = trace_strategy(get_strategy(name), actx.ctx, actx.axis_sizes)
    extracted = wire_total(tr.distribute + tr.reduce, actx.axis_sizes,
                           actx.ctx.outer_axes)
    declared = get_strategy(name).bytes_per_device(actx.ctx)
    assert (declared.inner, declared.outer) == (
        extracted.inner, extracted.outer), (name, declared, extracted)


# ---------------------------------------------------------------------------
# deliberately-wrong strategies must be rejected
# ---------------------------------------------------------------------------


class _SelfCountingWire(AllToAllStrategy):
    """Legacy drift: counts its own chunk as received wire bytes."""

    def bytes_per_device(self, ctx):
        pi = ctx.inner_shards
        return WireBytes(inner=3 * pi * ctx.capacity * 4,
                         outer=3 * (ctx.num_shards - pi) * ctx.capacity * 4)


class _NoOuterTier(AllToAllStrategy):
    """Claims a multi-pod exchange never crosses DCN."""

    def bytes_per_device(self, ctx):
        return WireBytes(
            inner=3 * (ctx.num_shards - 1) * ctx.capacity * 4, outer=0)


class _NoAccumulateFallback(TopKReduceStrategy):
    """Ignores fwd["accumulate"]: sparsifies and advances the carry on the
    full-batch accumulation path too."""

    def reduce(self, ctx, cold_loc, grads_flat, fwd):
        return super().reduce(ctx, cold_loc, grads_flat,
                              {**fwd, "accumulate": False})


@pytest.fixture
def scratch_registry():
    """Register test strategies, guaranteed unregistered afterwards."""
    added = []

    def add(name, strategy):
        register_strategy(name, strategy)
        added.append(name)
        return get_strategy(name)

    try:
        yield add
    finally:
        for name in added:
            _REGISTRY.pop(name, None)


def _rules(findings):
    return {f.rule for f in findings}


def test_bad_wire_model_rejected(scratch_registry):
    strat = scratch_registry("_bad_wire", _SelfCountingWire())
    _, findings = _check("_bad_wire", CONTEXTS["pod8"])
    assert "W-MATCH" in _rules(findings), findings
    # and the good strategy it shadows still passes, same geometry
    assert strat.bytes_per_device(CONTEXTS["pod8"].ctx).inner > \
        get_strategy("a2a").bytes_per_device(CONTEXTS["pod8"].ctx).inner


def test_missing_outer_tier_rejected(scratch_registry):
    scratch_registry("_no_outer", _NoOuterTier())
    _, findings = _check("_no_outer", CONTEXTS["multipod"])
    rules = _rules(findings)
    assert "W-OUTER" in rules, findings
    # single-pod contexts cannot see this lie
    _, findings_1pod = _check("_no_outer", CONTEXTS["pod8"])
    assert "W-OUTER" not in _rules(findings_1pod)


def test_missing_accumulate_fallback_rejected(scratch_registry):
    scratch_registry("_no_acc", _NoAccumulateFallback())
    _, findings = _check("_no_acc", CONTEXTS["pod8"])
    rules = _rules(findings)
    # the carry is mutated on the frozen path AND the collective pattern
    # no longer matches any exact strategy's reduce
    assert "A-FREEZE" in rules, findings
    assert "A-EXACT" in rules, findings


def test_audit_registry_fails_on_miswired_strategy(scratch_registry):
    scratch_registry("_bad_wire", _SelfCountingWire())
    report = audit_registry(engine_checks=False,
                            contexts=[CONTEXTS["pod8"]])
    assert not report["ok"]
    assert any(f["strategy"] == "_bad_wire" for f in report["findings"])
    # the built-ins stay clean even in a failing report
    assert all(f["strategy"] == "_bad_wire" for f in report["findings"])


def test_audit_registry_report_shape():
    report = audit_registry(strategies=["a2a", "topk_reduce"],
                            contexts=[CONTEXTS["multipod"]],
                            engine_checks=False)
    assert report["ok"] and report["num_findings"] == 0
    entry = report["strategies"]["a2a"]["multipod"]
    assert entry["declared"] == entry["extracted"]
    assert entry["collectives"]["distribute"]
    assert report["strategies"]["topk_reduce"]["multipod"]["stateful"]


# ---------------------------------------------------------------------------
# wire attribution math
# ---------------------------------------------------------------------------


def _coll(prim, axes, shape, dtype="float32", out_shape=None):
    return Collective(prim=prim, axes=axes, shapes=(shape,),
                      dtypes=(dtype,), out_shapes=(out_shape or shape,),
                      out_dtypes=(dtype,))


def test_collective_wire_tier_attribution():
    sizes = {"pod": 2, "data": 4}
    outer = ("pod",)
    # all_to_all over both axes: 8 chunks of 16 f32 rows each = 64B/chunk;
    # 3 inner peers, 4 cross-pod peers
    a2a = _coll("all_to_all", ("pod", "data"), (8, 16))
    assert collective_wire(a2a, sizes, outer) == WireBytes(
        inner=3 * 64, outer=4 * 64)
    # all_gather over pod only: one remote pod's whole buffer crosses DCN
    ag = _coll("all_gather", ("pod",), (128,))
    assert collective_wire(ag, sizes, outer) == WireBytes(
        inner=0, outer=128 * 4)
    # reduce_scatter counts RESULT-sized chunks per peer
    rs = _coll("reduce_scatter", ("data",), (64,), out_shape=(16,))
    assert collective_wire(rs, sizes, outer) == WireBytes(
        inner=3 * 16 * 4, outer=0)
    # degenerate single-participant group: nothing moves
    solo = _coll("all_to_all", ("pod",), (2, 4))
    assert collective_wire(solo, {"pod": 1}, ()) == WireBytes(0, 0)


def test_unmodeled_collective_raises():
    from repro.analysis.wire import UnmodeledCollectiveError

    weird = _coll("psum[grouped]", ("data",), (8,))
    with pytest.raises(UnmodeledCollectiveError):
        collective_wire(weird, {"data": 4}, ())
    missing_axis = _coll("all_gather", ("ghost",), (8,))
    with pytest.raises(UnmodeledCollectiveError):
        collective_wire(missing_axis, {"data": 4}, ())


# ---------------------------------------------------------------------------
# signature pinning: the extracted collective pattern per strategy
# ---------------------------------------------------------------------------

# (prim, axes) multiset each strategy's distribute+reduce emits on the
# (2, 4) multi-pod geometry. If a strategy's exchange structure changes,
# this pins the review: update BOTH the strategy's wire model and this
# table, and re-run `python -m repro.analysis.audit`.
PINNED_MULTIPOD_OPS = {
    "a2a": [("all_to_all", ("pod", "data"))] * 3,
    "allgather": [("all_gather", ("pod", "data")),
                  ("reduce_scatter", ("pod", "data"))],
    "psum_scatter": [("all_to_all", ("pod", "data"))] * 2
    + [("reduce_scatter", ("pod", "data"))],
    "hier_a2a": [("all_gather", ("pod",))]
    + [("all_to_all", ("data",))] * 3
    + [("reduce_scatter", ("pod",))],
    "compressed_reduce": [("all_to_all", ("pod", "data"))] * 4,
    "topk_reduce": [("all_to_all", ("pod", "data"))] * 4,
    "overlap_a2a": [("all_to_all", ("pod", "data"))] * 12,
}


@pytest.mark.parametrize("name", STRATEGIES)
def test_pinned_collective_signatures(name):
    actx = CONTEXTS["multipod"]
    tr = trace_strategy(get_strategy(name), actx.ctx, actx.axis_sizes)
    got = sorted((c.prim, c.axes) for c in tr.distribute + tr.reduce)
    assert got == sorted(PINNED_MULTIPOD_OPS[name]), (name, got)


def test_stateful_accumulate_path_is_exact():
    """The frozen-carry path puts only f32/int32 on the wire and returns
    the carry variable itself (jaxpr-level identity, not value
    comparison)."""
    actx = CONTEXTS["pod8"]
    for name in ("compressed_reduce", "topk_reduce"):
        tr = trace_strategy(get_strategy(name), actx.ctx, actx.axis_sizes)
        assert tr.stateful and tr.carry_passthrough, name
        assert set(tr.wire_dtypes_accumulate) <= {"float32", "int32"}, name


def test_contexts_cover_required_geometries():
    """The audit's default contexts include the single-device, single-pod,
    multi-pod, and production geometries the acceptance criteria name."""
    names = {a.name for a in build_contexts()}
    assert {"1dev", "pod8", "multipod", "production"} <= names
    prod = {a.name: a for a in build_contexts()}["production"]
    assert prod.ctx.num_shards == 512 and prod.ctx.outer_shards == 2
    assert prod.axis_sizes == {"pod": 2, "data": 16, "model": 16}


def test_registry_covers_all_builtins():
    assert set(STRATEGIES) <= set(list_strategies())


@pytest.mark.slow
def test_full_audit_passes_including_engine():
    """End-to-end: the shipped registry + engine seam is clean (the same
    gate `scripts/check.sh` runs via `python -m repro.analysis.audit`)."""
    report = audit_registry()
    assert report["ok"], report["findings"]
    eng = report["engine"]
    assert any("donation" in c for c in eng["checks"])
    assert any("resets the carry" in c for c in eng["checks"])


def test_batch_elems_never_clamps_hier_capacity():
    """Tracing batch size keeps hier_a2a's inner capacity at cap*Po (the
    unclamped regime the wire models are stated for)."""
    from repro.analysis.trace import batch_elems

    ctx = CONTEXTS["multipod"].ctx
    n = batch_elems(ctx)
    assert n >= ctx.capacity * ctx.outer_shards
    hier = get_strategy("hier_a2a")
    assert hier._inner_capacity(ctx, n) == \
        ctx.capacity * ctx.outer_shards


def test_wire_total_sums_both_tiers():
    sizes = {"pod": 2, "data": 4}
    ops = [_coll("all_to_all", ("pod", "data"), (8, 16)),
           _coll("all_gather", ("pod",), (128,))]
    total = wire_total(ops, sizes, ("pod",))
    assert total == WireBytes(inner=3 * 64, outer=4 * 64 + 512)
    assert jnp.asarray(total.total).item() == total.inner + total.outer
