"""Multi-process file-shard ownership: `ShardAssignment` math, the
`owned_shards` seam, chunk-local loader iteration (each host opens only
its owned chunk files), shuffle-within-owner, and save/restore — bit-exact
at a fixed host count, correct-by-reassignment across host-count changes.
"""

import numpy as np
import pytest

from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import (Cursor, ShardAssignment, ShardedLoader, get_source,
                        reassign_state, write_file_corpus)
from repro.launch.mesh import make_host_mesh
from repro.runtime import elastic

F = 1 << 11
CORPUS = dict(num_features=F, features_per_sample=8, signal_features=64,
              seed=0)


def _zipf(batch_size=32, num_batches=None):
    return get_source("zipf_sparse", batch_size=batch_size,
                      num_batches=num_batches, **CORPUS)


def _corpus(tmp_path, num_batches=12, batches_per_chunk=3, batch_size=32):
    d = str(tmp_path / "corpus")
    write_file_corpus(d, _zipf(batch_size=batch_size,
                               num_batches=num_batches),
                      batches_per_chunk=batches_per_chunk)
    return d


def _file_loader(d, host, hosts, **kw):
    kw.setdefault("placement", "host")
    kw.setdefault("prefetch", 0)
    return ShardedLoader(get_source("file_sparse", directory=d),
                         host_index=host, num_hosts=hosts, **kw)


def _key(batch):
    return np.asarray(batch["ids"]).tobytes()


# ---------------------------------------------------------------------------
# ShardAssignment: every chunk owned exactly once, contiguous, chunk-aligned
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_chunks,num_hosts", [
    (8, 2), (5, 3), (7, 7), (2, 4), (1, 5), (16, 3), (6, 4), (10, 4),
])
def test_chunk_assignment_partitions_exactly(num_chunks, num_hosts):
    """The load-bearing invariant: the per-host ranges tile [0, C) — every
    chunk owned by exactly one host, none dropped, even with hosts >
    chunks (trailing hosts own nothing)."""
    a = ShardAssignment.chunk_aligned(num_chunks, num_hosts,
                                      batches_per_chunk=4,
                                      num_batches=num_chunks * 4)
    owned = [c for h in range(num_hosts) for c in a.owned_chunks(h)]
    assert owned == list(range(num_chunks))          # exact cover, in order
    for h in range(num_hosts):
        r = a.owned_chunks(h)
        assert len(r) <= -(-num_chunks // num_hosts)  # ceil(C/H) bound
        # balanced split: no host starves while chunks remain (regression:
        # the ceil-greedy split gave (6, 4) -> sizes (2, 2, 2, 0))
        if num_chunks >= num_hosts:
            assert len(r) >= num_chunks // num_hosts >= 1
        assert a.steps_per_epoch(h) == len(a.owned_batches(h))
    for c in range(num_chunks):
        assert c in a.owned_chunks(a.chunk_owner(c))
    # batch-level cover too
    batches = [i for h in range(num_hosts) for i in a.owned_batches(h)]
    assert sorted(batches) == list(range(a.num_batches))


def test_chunk_assignment_uneven_last_chunk():
    """num_batches % batches_per_chunk != 0: the short last chunk yields
    exact per-host epoch lengths, not floors."""
    a = ShardAssignment.chunk_aligned(3, 2, batches_per_chunk=4,
                                      num_batches=10)   # sizes 4, 4, 2
    assert a.steps_per_epoch(0) == 8 and a.steps_per_epoch(1) == 2
    assert a.owned_batches(1) == [8, 9]
    assert list(a.chunk_batches(2)) == [8, 9]


def test_assignment_roundtrips_through_json_dict():
    import json
    a = ShardAssignment.chunk_aligned(5, 3, batches_per_chunk=4,
                                      num_batches=18)
    assert ShardAssignment.from_dict(
        json.loads(json.dumps(a.to_dict()))) == a
    s = ShardAssignment.strided(12, 4)
    assert ShardAssignment.from_dict(
        json.loads(json.dumps(s.to_dict()))) == s
    assert s.owned_batches(1) == [1, 5, 9]
    assert s.steps_per_epoch(1) == 3


def test_owned_shards_seam_declares_kind(tmp_path):
    """file_sparse returns chunk-aligned ranges; synthetic sources declare
    the stride; unbounded sources have nothing to divide."""
    fs = get_source("file_sparse", directory=_corpus(tmp_path))
    a = fs.owned_shards(0, 2)
    assert a.kind == "chunk" and a.num_chunks == 4
    assert _zipf(num_batches=8).owned_shards(1, 2).kind == "stride"
    lm = get_source("lm_markov", vocab_size=11, seq_len=4, batch_size=2,
                    num_batches=6)
    assert lm.owned_shards(0, 3).kind == "stride"
    assert _zipf(num_batches=None).owned_shards(0, 2) is None
    with pytest.raises(ValueError, match="out of range"):
        fs.owned_shards(2, 2)


# ---------------------------------------------------------------------------
# loader: owner-local iteration, file-open locality
# ---------------------------------------------------------------------------


def test_each_host_opens_only_owned_chunks(tmp_path):
    """THE acceptance criterion: with C chunks on H hosts, host h serves
    exactly its contiguous ⌈C/H⌉-chunk range and opens no other chunk
    file; the union over hosts is the whole corpus, each batch once."""
    d = _corpus(tmp_path, num_batches=12, batches_per_chunk=3)   # C=4
    src = _zipf(num_batches=12)
    want = [_key(src.batch(i)) for i in range(12)]
    seen = []
    for h in range(2):
        fs = get_source("file_sparse", directory=d)
        loader = ShardedLoader(fs, placement="host", prefetch=0,
                               host_index=h, num_hosts=2)
        assert loader.assignment.kind == "chunk"
        got = [_key(b) for b in loader.epoch()]
        assert got == want[6 * h: 6 * (h + 1)]       # contiguous shard
        assert fs.read_stats["unique_chunks"] == 2   # ceil(4/2), not 4
        assert fs.read_stats["chunk_loads"] == 2     # each file read ONCE
        seen += got
    assert sorted(seen) == sorted(want)

    # the stride baseline reads every chunk from every host (the H x read
    # amplification ownership removes)
    fs = get_source("file_sparse", directory=d, cache_chunks=1)
    stride = ShardedLoader(fs, placement="host", prefetch=0, host_index=0,
                           num_hosts=2, ownership="stride")
    assert stride.assignment is None
    list(stride.epoch())
    assert fs.read_stats["unique_chunks"] == 4


def test_uneven_chunks_and_prefetch_equivalence(tmp_path):
    """C % H != 0 plus a short last chunk: per-host epochs are exact owned
    counts, nothing is dropped; the prefetch thread serves the identical
    owned stream."""
    d = _corpus(tmp_path, num_batches=10, batches_per_chunk=4)  # sizes 4,4,2
    src = _zipf(num_batches=10)
    l0 = _file_loader(d, 0, 2)               # owns chunks 0,1 -> batches 0..7
    l1 = _file_loader(d, 1, 2)               # owns chunk 2 -> batches 8,9
    assert l0.steps_per_epoch == 8 and l1.steps_per_epoch == 2
    assert [_key(b) for b in l0.epoch()] == \
        [_key(src.batch(i)) for i in range(8)]
    assert [_key(b) for b in l1.epoch()] == \
        [_key(src.batch(i)) for i in (8, 9)]
    pre = _file_loader(d, 0, 2, prefetch=3)
    assert [_key(b) for b in pre.take(8)] == \
        [_key(src.batch(i)) for i in range(8)]


def test_hosts_exceed_chunks(tmp_path):
    """H > C: owning hosts work, chunk-less hosts refuse to construct with
    an actionable error instead of silently serving an empty epoch."""
    d = _corpus(tmp_path, num_batches=4, batches_per_chunk=2)    # C=2
    l0 = _file_loader(d, 0, 4)
    assert [_key(b) for b in l0.epoch()] == \
        [_key(_zipf(num_batches=4).batch(i)) for i in (0, 1)]
    with pytest.raises(ValueError, match="owns no chunks"):
        _file_loader(d, 3, 4)
    # assignment level: both chunks still owned exactly once
    a = get_source("file_sparse", directory=d).owned_shards(0, 4)
    assert [c for h in range(4) for c in a.owned_chunks(h)] == [0, 1]


def test_epoch_size_conflicts_with_ownership(tmp_path):
    d = _corpus(tmp_path)
    with pytest.raises(ValueError, match="epoch_size"):
        _file_loader(d, 0, 2, epoch_size=3)
    # ownership='stride' restores the old epoch_size semantics
    assert _file_loader(d, 0, 2, epoch_size=4,
                        ownership="stride").steps_per_epoch == 2


def test_stride_sources_unchanged_by_ownership_seam():
    """zipf/lm declare the stride kind: 'auto' must serve exactly the
    pre-ownership stream (no behaviour change for synthetic sources)."""
    src = _zipf(num_batches=6)
    auto = ShardedLoader(_zipf(num_batches=6), placement="host", prefetch=0,
                         host_index=1, num_hosts=2)
    forced = ShardedLoader(_zipf(num_batches=6), placement="host",
                           prefetch=0, host_index=1, num_hosts=2,
                           ownership="stride")
    assert auto.assignment is None and auto.assignment_kind == "stride"
    assert [_key(b) for b in auto.take(3)] == \
        [_key(b) for b in forced.take(3)] == \
        [_key(src.batch(i)) for i in (1, 3, 5)]


# ---------------------------------------------------------------------------
# shuffle: permutes chunks WITHIN an owner, keeps chunk locality
# ---------------------------------------------------------------------------


def test_shuffle_permutes_owned_chunks_only(tmp_path):
    d = _corpus(tmp_path, num_batches=24, batches_per_chunk=3)   # C=8
    src = _zipf(num_batches=24)
    fs = get_source("file_sparse", directory=d)
    loader = ShardedLoader(fs, placement="host", prefetch=0, host_index=0,
                           num_hosts=2, shuffle=True)
    own = [_key(src.batch(i)) for i in range(12)]    # chunks 0..3
    e0 = [_key(b) for b in loader.take(12)]
    e1 = [_key(b) for b in loader.take(12)]
    assert sorted(e0) == sorted(own) == sorted(e1)   # same owned set...
    assert e0 != e1                                  # ...fresh order
    # chunk locality: batches of each chunk stay consecutive and in order,
    # so every owned file is still one sequential read per epoch
    for epoch_keys in (e0, e1):
        starts = [epoch_keys.index(_key(src.batch(c * 3))) for c in range(4)]
        for c, s in enumerate(starts):
            assert epoch_keys[s:s + 3] == \
                [_key(src.batch(c * 3 + j)) for j in range(3)]
    assert fs.read_stats["unique_chunks"] == 4       # locality preserved
    # at most one sequential read per owned file per epoch (the LRU cache
    # may bridge an epoch boundary, saving a re-read)
    assert 4 <= fs.read_stats["chunk_loads"] <= 8


def test_shuffle_ownership_seek_reproduces_stream(tmp_path):
    """The owner-chunk permutation is a pure function of (seed, epoch,
    host): seeking mid-epoch reproduces the uninterrupted order."""
    d = _corpus(tmp_path, num_batches=12, batches_per_chunk=2)
    full = _file_loader(d, 1, 2, shuffle=True, prefetch=2).take(15)
    jumped = _file_loader(d, 1, 2, shuffle=True, prefetch=2)
    jumped.seek(Cursor(1, 4))
    for want, got in zip(full[10:], jumped.take(5), strict=True):
        for k in want:
            np.testing.assert_array_equal(want[k], got[k])


# ---------------------------------------------------------------------------
# save/restore: bit-exact at fixed H, reassignment across H changes
# ---------------------------------------------------------------------------


def _cfg(**kw):
    base = dict(num_features=F, max_features_per_sample=8, iterations=2,
                learning_rate=1.0, max_hot=16, optimizer="adagrad")
    base.update(kw)
    return DPMRConfig(**base)


def test_resume_bit_exact_fixed_hosts_with_shuffle(tmp_path):
    """Acceptance criterion: engine + owned file_sparse loader (host 0 of
    2, shuffled), trained/saved/restored at the SAME host count, resumes
    bit-identically — including the per-epoch chunk permutation."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    d = _corpus(tmp_path, num_batches=16, batches_per_chunk=2,
                batch_size=64)
    ckdir = str(tmp_path / "ck")

    def loader():
        return ShardedLoader(get_source("file_sparse", directory=d), mesh,
                             host_index=0, num_hosts=2, shuffle=True)

    full = DPMREngine(cfg, mesh)
    full_hist = full.fit_sgd(loader(), steps=11)     # crosses epoch boundary

    part = DPMREngine(cfg, mesh)
    part.fit_sgd(loader(), steps=5)
    part.save(ckdir)

    resumed = DPMREngine(cfg, mesh)
    resumed_loader = loader()
    manifest = resumed.restore(ckdir, loader=resumed_loader)
    data = manifest["extra"]["data"]
    assert data["ownership"] == "chunk"
    assert data["assignment"]["num_chunks"] == 8
    assert resumed_loader.cursor == Cursor(0, 5)
    part_hist = resumed.fit_sgd(resumed_loader, steps=6)

    assert full_hist[5:] == part_hist
    for a, b in zip(full.state, resumed.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_host_count_change_errors_then_reassigns(tmp_path):
    """H=2 -> H=3 restore: default refuses; 'reassign' resumes at the
    epoch boundary under the new assignment, where every chunk is again
    owned exactly once and none dropped."""
    d = _corpus(tmp_path, num_batches=12, batches_per_chunk=2)   # C=6
    saved = _file_loader(d, 0, 2)
    saved.take(8)                                    # mid-epoch 1
    state = saved.state_dict()
    assert state["cursor"] == {"epoch": 1, "step": 2}

    new = _file_loader(d, 1, 3)
    with pytest.raises(ValueError, match="reassign"):
        new.load_state_dict(state)
    with pytest.warns(RuntimeWarning, match="reassigning"):
        new.load_state_dict(state, on_host_change="reassign")
    assert new.cursor == Cursor(1, 0)                # epoch kept, step reset

    # correctness-by-reassignment: the three new loaders tile the corpus
    src = _zipf(num_batches=12)
    seen = []
    for h in range(3):
        loader = _file_loader(d, h, 3)
        loader.load_state_dict(state, on_host_change="reassign")
        seen += [_key(b) for b in loader.epoch()]
    assert sorted(seen) == sorted(_key(src.batch(i)) for i in range(12))


def test_engine_restore_reassigns_across_host_change(tmp_path):
    """The full elastic path: checkpoint written under H=1, restored into
    an H=2 loader with on_host_change='reassign' — training continues on
    this host's new shard from the epoch boundary."""
    mesh = make_host_mesh(1, 1)
    d = _corpus(tmp_path, num_batches=8, batches_per_chunk=2, batch_size=64)
    ckdir = str(tmp_path / "ck")
    eng = DPMREngine(_cfg(), mesh)
    eng.fit_sgd(ShardedLoader(get_source("file_sparse", directory=d), mesh),
                steps=3)
    eng.save(ckdir)

    resumed = DPMREngine(_cfg(), mesh)
    half = ShardedLoader(get_source("file_sparse", directory=d), mesh,
                         host_index=0, num_hosts=2)
    with pytest.raises(ValueError, match="num_hosts"):
        resumed.restore(ckdir, loader=half)
    with pytest.warns(RuntimeWarning, match="reassigning"):
        manifest = resumed.restore(ckdir, loader=half,
                                   on_host_change="reassign")
    assert manifest["extra"]["data"]["num_hosts"] == 1
    assert half.cursor == Cursor(0, 0)
    hist = resumed.fit_sgd(half, steps=2)            # serves the new shard
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])


def test_reshard_data_state_helpers():
    """`elastic.reshard_data_state` == `reassign_state`: epoch survives,
    step resets, stale assignment dropped, host identity rewritten."""
    state = {"cursor": {"epoch": 3, "step": 7}, "num_hosts": 2,
             "host_index": 1, "ownership": "chunk",
             "assignment": {"kind": "chunk", "num_hosts": 2,
                            "num_batches": 8, "batches_per_chunk": 2,
                            "num_chunks": 4, "chunk_ranges": [[0, 2],
                                                              [2, 4]]},
             "shuffle": True, "shuffle_seed": 5, "source": "file_sparse",
             "batch_size": 32}
    for fn in (reassign_state, elastic.reshard_data_state):
        out = fn(state, 4, 2)
        assert out["cursor"] == {"epoch": 3, "step": 0}
        assert out["num_hosts"] == 4 and out["host_index"] == 2
        assert "assignment" not in out
        assert out["shuffle_seed"] == 5          # shuffle identity survives
        assert state["cursor"]["step"] == 7      # input not mutated


def test_restored_cursor_warns_on_foreign_host_or_geometry(tmp_path):
    d = _corpus(tmp_path, num_batches=12, batches_per_chunk=3)
    state = _file_loader(d, 0, 2).state_dict()
    other_host = _file_loader(d, 1, 2)
    with pytest.warns(RuntimeWarning, match="host 0"):
        other_host.load_state_dict(state)
    # same host count, different chunk geometry -> different stream
    d2 = _corpus(tmp_path / "other", num_batches=12, batches_per_chunk=2)
    regeom = _file_loader(d2, 0, 2)
    with pytest.warns(RuntimeWarning, match="different chunk assignment"):
        regeom.load_state_dict(state)
    # stride cursor into a chunk-owned loader -> ordering mismatch
    stride_state = ShardedLoader(_zipf(num_batches=12), placement="host",
                                 host_index=0, num_hosts=2).state_dict()
    chunked = _file_loader(d, 0, 2)
    with pytest.warns(RuntimeWarning, match="ownership"):
        chunked.load_state_dict(stride_state)


def test_ownership_rejects_unknown_mode(tmp_path):
    with pytest.raises(ValueError, match="ownership"):
        _file_loader(_corpus(tmp_path), 0, 2, ownership="nope")
