"""GPipe pipeline parallelism: schedule correctness + gradient flow."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 4, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = run_py("""
import json
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.train.pipeline import make_pp_mesh, pipeline_apply

S, M, B, D = 4, 8, 2, 16
mesh = make_pp_mesh(S)
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(0, 0.3, size=(S, D, D)), jnp.float32)
x = jnp.asarray(rng.normal(size=(M, B, D)), jnp.float32)

def stage_fn(w_s, h):
    return jnp.tanh(h @ w_s)

with compat.set_mesh(mesh):
    y_pipe = pipeline_apply({"w": w}, x,
                            lambda p, h: stage_fn(p["w"], h), mesh)

# sequential oracle
y_ref = x
for s in range(S):
    y_ref = jnp.tanh(y_ref @ w[s])
diff = float(jnp.max(jnp.abs(y_pipe - y_ref)))

# gradient through the pipeline
def loss(w):
    y = pipeline_apply({"w": w}, x, lambda p, h: stage_fn(p["w"], h), mesh)
    return jnp.sum(jnp.sin(y))

def loss_ref(w):
    y = x
    for s in range(S):
        y = jnp.tanh(y @ w[s])
    return jnp.sum(jnp.sin(y))

with compat.set_mesh(mesh):
    g_pipe = jax.grad(loss)(w)
g_ref = jax.grad(loss_ref)(w)
gdiff = float(jnp.max(jnp.abs(g_pipe - g_ref)))
print(json.dumps({"fwd": diff, "bwd": gdiff}))
""")
    assert out["fwd"] < 1e-5, out
    assert out["bwd"] < 1e-5, out


def test_bubble_fraction():
    from repro.train.pipeline import bubble_fraction

    assert bubble_fraction(4, 8) == 3 / 11
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(8, 32) < 0.2
