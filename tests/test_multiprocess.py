"""Real multi-process execution (tentpole of the distributed runtime):
two OS processes, one `jax.distributed` coordinator, one global mesh —
parity with the single-process emulation, and async-checkpoint restore
across an actual kill + relaunch at a different host count.

Everything runs in subprocesses: the pytest process itself must never
initialize jax.distributed (XLA_FLAGS and the coordinator are per-process,
one-shot). Marked slow like the other subprocess suites.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)      # --local-devices owns the device count
    return env


def _train(extra, steps, save_every=100, ckpt="", async_ckpt=False):
    args = [sys.executable, "-m", "repro.launch.train", "--sparse",
            "--strategy", "a2a", "--features", "1024", "--batch", "32",
            "--sparse-batches", "64", "--mesh-data", "4", "--prefetch", "0",
            "--json", "--log-every", "0", "--steps", str(steps),
            "--save-every", str(save_every)]
    if ckpt:
        args += ["--ckpt", ckpt]
    if async_ckpt:
        args += ["--async-ckpt"]
    return subprocess.Popen(args + extra, env=_env(),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)


def _summary(proc, timeout=600):
    out, err = proc.communicate(timeout=timeout)
    assert proc.returncode == 0, err[-4000:]
    return json.loads(out.strip().splitlines()[-1])


def test_two_process_parity_gate():
    """The exact gate nightly CI runs: a real 2-process coordinated run
    bit-matches the `--hosts 2 --host-id -1` emulation (final parameter
    digest + deterministic float64 eval loss; step metrics within 1 ulp
    tolerance). scripts/check_multiprocess.py owns the comparison."""
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts",
                                      "check_multiprocess.py")],
        env={**_env(), "REPRO_MP_PORT": "12747"},
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_async_ckpt_survives_kill_and_elastic_restart(tmp_path):
    """Kill a live 2-process run mid-training; the async-written
    checkpoint restores into a SINGLE-process relaunch (new data-plane
    host count, same global mesh) which resumes and finishes — the
    paper's restartable outer loop over real process boundaries."""
    ckpt = str(tmp_path / "ck")
    mp = ["--coordinator", "127.0.0.1:12749", "--num-processes", "2",
          "--local-devices", "2"]
    p1 = _train([*mp, "--process-id", "1"], steps=40, save_every=2,
                ckpt=ckpt, async_ckpt=True)
    p0 = _train([*mp, "--process-id", "0"], steps=40, save_every=2,
                ckpt=ckpt, async_ckpt=True)
    try:
        # wait for at least one COMPLETE checkpoint (manifest present)
        deadline = time.time() + 300
        while time.time() < deadline:
            steps = [d for d in (os.listdir(ckpt) if os.path.isdir(ckpt)
                                 else [])
                     if d.startswith("step_") and not d.endswith(".tmp")
                     and os.path.exists(os.path.join(ckpt, d,
                                                     "manifest.json"))]
            if steps:
                break
            if p0.poll() is not None and p1.poll() is not None:
                pytest.fail("run exited before writing a checkpoint: "
                            + p0.communicate()[1][-2000:])
            time.sleep(0.5)
        else:
            pytest.fail("no checkpoint appeared within the deadline")
        # kill one process, then the other — the cluster is gone
        p1.send_signal(signal.SIGKILL)
        p0.send_signal(signal.SIGKILL)
    finally:
        for p in (p0, p1):
            if p.poll() is None:
                p.kill()
            p.communicate()

    # relaunch at H=1 (4 local devices, same 4-device global mesh): the
    # cursor was recorded under num_hosts=2, so restore reassigns
    # ownership (reshard_data_state semantics) and training continues
    resumed = _summary(_train(["--local-devices", "4"], steps=8,
                              save_every=4, ckpt=ckpt))
    assert resumed["last_step"] == 8
    assert 1 <= len(resumed["losses"]) <= 7      # resumed, not restarted
    assert resumed["hosts"] == 1 and resumed["num_processes"] == 1


def test_all_hosts_emulation_equals_stride_union():
    """`--host-id -1` serves exactly the concatenation of every host's
    stride batches (pure data-plane check, no jax needed)."""
    import numpy as np

    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.data import get_source
    from repro.runtime.multiprocess import emulate_all_hosts

    src = get_source("zipf_sparse", batch_size=8, num_batches=12,
                     num_features=1 << 10, features_per_sample=8, seed=3)
    wrapped = emulate_all_hosts(src, 3)
    assert wrapped.batch_size == 24 and wrapped.num_batches == 4
    got = wrapped.batch(2)
    want = {k: np.concatenate([np.asarray(src.batch(2 * 3 + h)[k])
                               for h in range(3)])
            for k in got}
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), want[k])
