"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # don't abort collection without it

from hypothesis import given, settings, strategies as st

from repro.api import autotune
from repro.api.strategies import (StrategyContext, get_strategy,
                                  list_strategies)
from repro.core import hot_sharding, sparse
from repro.kernels import ops
from repro.optim import compression

SET = dict(max_examples=25, deadline=None)

# the built-in registry at import time (other test modules register
# throwaway strategies at run time; the tuner properties are stated over
# the shipped set)
BUILTINS = tuple(list_strategies())


@st.composite
def id_arrays(draw, max_n=96, max_f=96):
    n = draw(st.integers(4, max_n))
    f = draw(st.integers(8, max_f))
    ids = draw(st.lists(st.integers(-1, f - 1), min_size=n, max_size=n))
    return np.asarray(ids, np.int32), f


@given(id_arrays(), st.integers(1, 4))
@settings(**SET)
def test_route_roundtrip_identity(ids_f, logp):
    """distributeParameters then restoreDocuments is the identity lookup
    for ANY id multiset, for any shard count, when capacity suffices."""
    ids, f = ids_f
    p = 2 ** logp
    f = -(-f // p) * p
    block = f // p
    cap = int(ids.size)                       # capacity always sufficient
    r = sparse.route_build(jnp.asarray(ids), p, block, cap)
    assert int(r.overflow) == 0
    table = np.arange(1, f + 1, dtype=np.float32)  # distinct values
    req = np.asarray(r.req_ids)
    resp = np.zeros((p, cap), np.float32)
    for o in range(p):
        resp[o] = np.where(req[o] >= 0, table[np.clip(req[o], 0, f - 1)], 0)
    vals = np.asarray(sparse.route_return(r, jnp.asarray(resp)))
    expect = np.where(ids >= 0, table[np.clip(ids, 0, f - 1)], 0)
    np.testing.assert_allclose(vals, expect)


@given(id_arrays(), st.integers(1, 3))
@settings(**SET)
def test_grad_conservation(ids_f, logp):
    """The reduce shuffle conserves total gradient mass per feature."""
    ids, f = ids_f
    p = 2 ** logp
    f = -(-f // p) * p
    block = f // p
    rng = np.random.default_rng(0)
    grads = rng.normal(size=ids.shape).astype(np.float32)
    r = sparse.route_build(jnp.asarray(ids), p, block, int(ids.size))
    send = np.asarray(sparse.combine_grads(r, jnp.asarray(grads)))
    # total mass (valid slots only) is conserved through the combiner
    np.testing.assert_allclose(send.sum(), grads[ids >= 0].sum(), atol=1e-4)


@given(st.integers(2, 6), st.integers(10, 200))
@settings(**SET)
def test_segment_sum_mass_conservation(nruns, n):
    rng = np.random.default_rng(nruns * n)
    ids = np.sort(rng.integers(0, nruns, size=n)).astype(np.int32)
    g = rng.normal(size=n).astype(np.float32)
    out = ops.segment_sum_sorted(jnp.asarray(ids), jnp.asarray(g),
                                 impl="pallas_interpret", block=32)
    np.testing.assert_allclose(float(jnp.sum(out)), g.sum(), atol=1e-4)
    # one emission per distinct id
    assert int(jnp.sum(out != 0)) <= nruns


@given(st.integers(0, 2**31 - 2), st.integers(1, 64))
@settings(**SET)
def test_hot_split_partition(seed, max_hot):
    """hot + cold is a partition: every valid id goes to exactly one side."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(-1, 1000, size=64).astype(np.int32)
    counts = hot_sharding.feature_counts(jnp.asarray(ids), 1000)
    hot = hot_sharding.select_hot(counts, 0.01, max_hot)
    slot, is_hot, cold = hot_sharding.split_hot(jnp.asarray(ids), hot)
    is_hot = np.asarray(is_hot)
    cold = np.asarray(cold)
    valid = ids >= 0
    assert np.all((cold[valid] >= 0) != is_hot[valid])
    assert np.all(cold[~valid] == -1)
    # hot slots decode back to the original id
    hot_np = np.asarray(hot)
    sl = np.asarray(slot)
    assert np.all(hot_np[sl[is_hot]] == ids[is_hot])


@given(st.integers(0, 10_000), st.integers(1, 8))
@settings(**SET)
def test_compression_error_feedback_bounded(seed, blocks):
    """Quantization error never exceeds half a quant step per element, and
    error feedback keeps the CUMULATIVE error bounded over steps."""
    rng = np.random.default_rng(seed)
    n = blocks * 64
    g = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    err = jnp.zeros_like(g)
    total_applied = jnp.zeros_like(g)
    total_true = jnp.zeros_like(g)
    for _ in range(4):
        q, scale = compression._quantize(
            jnp.pad(g + err, (0, (-n) % compression.BLOCK)))
        deq = compression._dequantize(q, scale, n)
        new_err = g + err - deq
        total_applied = total_applied + deq
        total_true = total_true + g
        err = new_err
    # with error feedback, cumulative applied = cumulative true - last error
    np.testing.assert_allclose(np.asarray(total_applied + err),
                               np.asarray(total_true), rtol=1e-5, atol=1e-5)


@given(st.integers(0, 1000))
@settings(**SET)
def test_cross_entropy_matches_numpy(seed):
    from repro.models.common import cross_entropy

    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(2, 5, 11)).astype(np.float32)
    labels = rng.integers(0, 11, size=(2, 5)).astype(np.int32)
    got = float(cross_entropy(jnp.asarray(logits), jnp.asarray(labels)))
    # numpy oracle
    e = np.exp(logits - logits.max(-1, keepdims=True))
    p = e / e.sum(-1, keepdims=True)
    nll = -np.log(np.take_along_axis(p, labels[..., None], -1))[..., 0]
    np.testing.assert_allclose(got, nll.mean(), rtol=1e-5)


@given(st.sampled_from(["train_4k", "prefill_32k", "decode_32k"]),
       st.sampled_from(["granite-8b", "mixtral-8x22b", "xlstm-125m"]))
@settings(max_examples=9, deadline=None)
def test_batch_defs_consistent(shape_name, arch):
    """Input specs: batch dims always equal the shape's global batch."""
    from repro.configs import SHAPES
    from repro.models import registry
    from repro.sharding import Annotated

    spec = registry.get_spec(arch)
    shape = SHAPES[shape_name]
    defs = registry.batch_defs(spec, shape)
    toks = defs["tokens"] if "tokens" in defs else defs["cache"]
    leaves = jax.tree.leaves(
        defs, is_leaf=lambda x: isinstance(x, Annotated))
    assert all(isinstance(l, Annotated) for l in leaves)
    if shape.kind != "decode":
        assert defs["tokens"].shape == (shape.global_batch, shape.seq_len)
    else:
        assert defs["tokens"].shape == (shape.global_batch, 1)


# ---------------------------------------------------------------------------
# analytic geometry autotuner (repro.api.autotune)
# ---------------------------------------------------------------------------


@st.composite
def geometries(draw):
    """Analytic StrategyContexts: power-of-two shard counts with the pod
    factor dividing them, paper-plausible block/capacity ranges."""
    po = draw(st.sampled_from([1, 2, 4]))
    pi = 2 ** draw(st.integers(1, 6))
    block = 2 ** draw(st.integers(7, 14))
    cap = 2 ** draw(st.integers(4, 12))
    frac = draw(st.sampled_from([0.05, 0.25, 1.0]))
    return StrategyContext(axes=(), num_shards=po * pi, block_size=block,
                           capacity=cap, outer_shards=po, topk_frac=frac)


bandwidths = st.floats(1.0, 2000.0)


@given(geometries(), bandwidths, bandwidths)
@settings(**SET)
def test_autotuner_choice_is_optimal(ctx, inner_gbps, outer_gbps):
    """The chosen strategy never costs more than ANY candidate under the
    same per-tier bandwidths (independently recomputed costs)."""
    bw = autotune.WireBandwidth(inner_gbps, outer_gbps)
    ranked = autotune.score_strategies(ctx, bw, strategies=BUILTINS)
    chosen = autotune.choose_strategy(ctx, bw, strategies=BUILTINS)
    assert chosen == ranked[0].name
    for name in BUILTINS:
        cost = autotune.wire_cost(
            get_strategy(name).bytes_per_device(ctx), bw)
        assert ranked[0].cost_s <= cost


@given(geometries(), bandwidths, bandwidths, bandwidths)
@settings(**SET)
def test_autotuner_dcn_monotonicity(ctx, inner_gbps, bw_a, bw_b):
    """Raising the DCN cost (slower outer tier) never flips the tuner
    toward a strategy with MORE outer bytes — the exchange argument
    (c1-c2)(1/bw1-1/bw2) <= 0, stated over the real registry."""
    fast, slow = max(bw_a, bw_b), min(bw_a, bw_b)

    def pick(outer_gbps):
        return autotune.score_strategies(
            ctx, autotune.WireBandwidth(inner_gbps, outer_gbps),
            strategies=BUILTINS)[0]

    assert pick(slow).wire.outer <= pick(fast).wire.outer


@given(geometries(), bandwidths, bandwidths)
@settings(**SET)
def test_autotuner_ranking_deterministic(ctx, inner_gbps, outer_gbps):
    """Same inputs -> same ranking, and ties break by name (the ranking
    is exactly sorted by (cost, name))."""
    bw = autotune.WireBandwidth(inner_gbps, outer_gbps)
    r1 = autotune.score_strategies(ctx, bw, strategies=BUILTINS)
    r2 = autotune.score_strategies(ctx, bw, strategies=BUILTINS)
    assert [s.name for s in r1] == [s.name for s in r2]
    keys = [(s.cost_s, s.name) for s in r1]
    assert keys == sorted(keys)


@given(geometries(), bandwidths, bandwidths)
@settings(**SET)
def test_autotuner_require_exact_filters_lossy(ctx, inner_gbps, outer_gbps):
    """require_exact drops exactly the strategies that would carry
    error-feedback state on THIS geometry, and never all of them (the
    exact built-ins admit every geometry)."""
    bw = autotune.WireBandwidth(inner_gbps, outer_gbps)
    exact = autotune.score_strategies(ctx, bw, require_exact=True,
                                      strategies=BUILTINS)
    assert exact and all(not s.lossy for s in exact)
    for s in exact:
        assert get_strategy(s.name).init_carry(ctx) is None
