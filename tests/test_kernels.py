"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,k", [(8, 16), (64, 32), (128, 64), (33, 7)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_sigmoid_grad_sweep(b, k, dtype):
    rng = np.random.default_rng(b * 100 + k)
    vals = jnp.asarray(rng.normal(size=(b, k)).astype(dtype))
    theta = jnp.asarray(rng.normal(size=(b, k)).astype(dtype))
    y = jnp.asarray(rng.integers(0, 2, size=(b,)).astype(np.int32))
    g0, p0, n0 = ops.sigmoid_grad(vals, theta, y, impl="jnp")
    g1, p1, n1 = ops.sigmoid_grad(vals, theta, y, impl="pallas_interpret",
                                  block_b=16)
    tol = 1e-5 if dtype == np.float32 else 2e-3
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=tol)
    np.testing.assert_allclose(np.asarray(p0), np.asarray(p1), atol=tol)
    np.testing.assert_allclose(np.asarray(n0), np.asarray(n1), atol=tol)


@pytest.mark.parametrize("n,block", [(64, 16), (256, 32), (256, 256),
                                     (1024, 128), (100, 100)])
@pytest.mark.parametrize("nruns", [3, 40])
def test_segment_sum_sweep(n, block, nruns):
    rng = np.random.default_rng(n + nruns)
    ids = np.sort(rng.integers(0, nruns, size=n - n // 8)).astype(np.int32)
    ids = np.concatenate([ids, np.full(n // 8, -1, np.int32)])
    # padding must sort LAST: engine sorts with key int32max; emulate
    ids = np.concatenate([np.sort(ids[ids >= 0]), ids[ids < 0]])
    g = rng.normal(size=(n,)).astype(np.float32)
    r0 = ops.segment_sum_sorted(jnp.asarray(ids), jnp.asarray(g), impl="jnp")
    r1 = ops.segment_sum_sorted(jnp.asarray(ids), jnp.asarray(g),
                                impl="pallas_interpret", block=block)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1), atol=1e-5)
    # totals preserved
    np.testing.assert_allclose(float(jnp.sum(r1)), float(np.sum(g[ids >= 0])),
                               atol=1e-4)


def test_segment_sum_run_spanning_blocks():
    """A single run spanning 4 blocks must emit exactly one total."""
    n, block = 64, 16
    ids = jnp.zeros((n,), jnp.int32)
    g = jnp.ones((n,), jnp.float32)
    out = ops.segment_sum_sorted(ids, g, impl="pallas_interpret", block=block)
    ref_out = ops.segment_sum_sorted(ids, g, impl="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out))
    assert float(out[-1]) == n
    assert float(jnp.sum(out)) == n


@pytest.mark.parametrize("shapes", [
    (1, 32, 2, 2, 8), (2, 64, 4, 2, 16), (2, 128, 8, 1, 32),
    (1, 64, 6, 3, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shapes, dtype, causal):
    b, s, h, kh, d = shapes
    rng = np.random.default_rng(sum(shapes))
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    o_ker = ops.flash_attention(q, k, v, causal=causal,
                                impl="pallas_interpret",
                                block_q=16, block_k=16)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_ker, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_gqa_group_mapping():
    """GQA: each q head must attend to ITS kv head, not head 0."""
    b, s, h, kh, d = 1, 16, 4, 2, 8
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, impl="pallas_interpret",
                              block_q=8, block_k=8)
    # head 3 belongs to kv head 1: zeroing kv head 0 must not change it
    k0 = k.at[:, :, 0].set(0.0)
    v0 = v.at[:, :, 0].set(0.0)
    out2 = ops.flash_attention(q, k0, v0, impl="pallas_interpret",
                               block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out[:, :, 3]),
                               np.asarray(out2[:, :, 3]), atol=1e-6)
    assert not np.allclose(np.asarray(out[:, :, 0]),
                           np.asarray(out2[:, :, 0]))
