"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,k", [(8, 16), (64, 32), (128, 64), (33, 7)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_sigmoid_grad_sweep(b, k, dtype):
    rng = np.random.default_rng(b * 100 + k)
    vals = jnp.asarray(rng.normal(size=(b, k)).astype(dtype))
    theta = jnp.asarray(rng.normal(size=(b, k)).astype(dtype))
    y = jnp.asarray(rng.integers(0, 2, size=(b,)).astype(np.int32))
    g0, p0, n0 = ops.sigmoid_grad(vals, theta, y, impl="jnp")
    g1, p1, n1 = ops.sigmoid_grad(vals, theta, y, impl="pallas_interpret",
                                  block_b=16)
    tol = 1e-5 if dtype == np.float32 else 2e-3
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=tol)
    np.testing.assert_allclose(np.asarray(p0), np.asarray(p1), atol=tol)
    np.testing.assert_allclose(np.asarray(n0), np.asarray(n1), atol=tol)


@pytest.mark.parametrize("n,block", [(64, 16), (256, 32), (256, 256),
                                     (1024, 128), (100, 100)])
@pytest.mark.parametrize("nruns", [3, 40])
def test_segment_sum_sweep(n, block, nruns):
    rng = np.random.default_rng(n + nruns)
    ids = np.sort(rng.integers(0, nruns, size=n - n // 8)).astype(np.int32)
    ids = np.concatenate([ids, np.full(n // 8, -1, np.int32)])
    # padding must sort LAST: engine sorts with key int32max; emulate
    ids = np.concatenate([np.sort(ids[ids >= 0]), ids[ids < 0]])
    g = rng.normal(size=(n,)).astype(np.float32)
    r0 = ops.segment_sum_sorted(jnp.asarray(ids), jnp.asarray(g), impl="jnp")
    r1 = ops.segment_sum_sorted(jnp.asarray(ids), jnp.asarray(g),
                                impl="pallas_interpret", block=block)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1), atol=1e-5)
    # totals preserved
    np.testing.assert_allclose(float(jnp.sum(r1)), float(np.sum(g[ids >= 0])),
                               atol=1e-4)


def test_segment_sum_run_spanning_blocks():
    """A single run spanning 4 blocks must emit exactly one total."""
    n, block = 64, 16
    ids = jnp.zeros((n,), jnp.int32)
    g = jnp.ones((n,), jnp.float32)
    out = ops.segment_sum_sorted(ids, g, impl="pallas_interpret", block=block)
    ref_out = ops.segment_sum_sorted(ids, g, impl="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out))
    assert float(out[-1]) == n
    assert float(jnp.sum(out)) == n


@pytest.mark.parametrize("shapes", [
    (1, 32, 2, 2, 8), (2, 64, 4, 2, 16), (2, 128, 8, 1, 32),
    (1, 64, 6, 3, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shapes, dtype, causal):
    b, s, h, kh, d = shapes
    rng = np.random.default_rng(sum(shapes))
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    o_ker = ops.flash_attention(q, k, v, causal=causal,
                                impl="pallas_interpret",
                                block_q=16, block_k=16)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o_ref, np.float32), np.asarray(o_ker, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_gqa_group_mapping():
    """GQA: each q head must attend to ITS kv head, not head 0."""
    b, s, h, kh, d = 1, 16, 4, 2, 8
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, impl="pallas_interpret",
                              block_q=8, block_k=8)
    # head 3 belongs to kv head 1: zeroing kv head 0 must not change it
    k0 = k.at[:, :, 0].set(0.0)
    v0 = v.at[:, :, 0].set(0.0)
    out2 = ops.flash_attention(q, k0, v0, impl="pallas_interpret",
                               block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out[:, :, 3]),
                               np.asarray(out2[:, :, 3]), atol=1e-6)
    assert not np.allclose(np.asarray(out[:, :, 0]),
                           np.asarray(out2[:, :, 0]))


# ---------------------------------------------------------------------------
# select_pack: fused compensate + rank + pack (topk_reduce's hot path)
# ---------------------------------------------------------------------------


def _select_pack_case(p, cap, seed, live_frac=0.8):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 4 * cap, size=(p, cap)).astype(np.int32)
    dead = rng.random(size=(p, cap)) > live_frac
    ids = np.where(dead, -1, ids)
    send = np.where(ids >= 0, rng.normal(size=(p, cap)), 0.0).astype(
        np.float32)
    carry = np.where(ids >= 0, rng.normal(size=(p, cap)), 0.0).astype(
        np.float32)
    return jnp.asarray(send), jnp.asarray(ids), jnp.asarray(carry)


@pytest.mark.parametrize("p,cap,k", [
    (1, 8, 2), (4, 64, 16), (3, 33, 7), (8, 128, 128),   # k == cap: frac=1.0
    (2, 16, 1), (5, 40, 39),
])
def test_select_pack_bit_exact_sweep(p, cap, k):
    """The kernel's selection set AND output order must match the XLA
    chain bit-for-bit: ranking reproduces jax.lax.top_k's total order
    (descending |value|, ties by position) and packing is a one-hot
    matmul with exactly one live term, so no float op reassociates."""
    send, ids, carry = _select_pack_case(p, cap, seed=p * 1000 + cap + k)
    want = ref.select_pack_ref(send, ids, carry, k=k)
    got = ops.select_pack(send, ids, carry, k=k, impl="pallas_interpret")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_select_pack_edge_rows():
    """Empty rows, all-dead rows, and rows with fewer live slots than k:
    dead picks carry id -1 and value 0, exactly like the chain."""
    p, cap, k = 4, 16, 8
    send, ids, carry = _select_pack_case(p, cap, seed=0)
    ids = ids.at[1].set(-1)                       # row 1 fully dead
    ids = ids.at[2, 3:].set(-1)                   # row 2: 3 live < k
    send = jnp.where(ids >= 0, send, 0.0)
    carry = jnp.where(ids >= 0, carry, 0.0)
    want = ref.select_pack_ref(send, ids, carry, k=k)
    got = ops.select_pack(send, ids, carry, k=k, impl="pallas_interpret")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    vals_k, ids_k, resid = got
    assert np.all(np.asarray(ids_k[1]) == -1)
    assert np.all(np.asarray(vals_k[1]) == 0.0)
    # a row with <= k live slots sends everything: residual all zero
    assert np.all(np.asarray(resid[2]) == 0.0)


def test_select_pack_duplicate_keys_tiebreak():
    """Equal |values| must break ties by position (top_k's order) — the
    case that catches a ranking comparator that is not a total order."""
    p, cap, k = 1, 12, 4
    ids = jnp.arange(12, dtype=jnp.int32).reshape(p, cap)
    send = jnp.full((p, cap), 0.5, jnp.float32)
    send = send.at[0, 7].set(-0.5)                # same |.|, negative
    carry = jnp.zeros((p, cap), jnp.float32)
    want = ref.select_pack_ref(send, ids, carry, k=k)
    got = ops.select_pack(send, ids, carry, k=k, impl="pallas_interpret")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_select_pack_capacity_fallback():
    """Above MAX_CAPACITY the dispatcher silently runs the XLA chain (the
    seam never errors with geometry); the raw kernel refuses."""
    from repro.kernels import select_pack as sp

    p, cap, k = 2, sp.MAX_CAPACITY + 8, 4
    send, ids, carry = _select_pack_case(p, cap, seed=3)
    want = ref.select_pack_ref(send, ids, carry, k=k)
    got = ops.select_pack(send, ids, carry, k=k, impl="pallas_interpret")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    with pytest.raises(ValueError, match="MAX_CAPACITY"):
        sp.select_pack(send, ids, carry, k=k, interpret=True)


# ---------------------------------------------------------------------------
# owner_accumulate: the reverse-shuffle scatter-add behind the seam
# ---------------------------------------------------------------------------


def _routing_case(p, cap, f, seed, integer_grads=False):
    rng = np.random.default_rng(seed)
    ids = rng.integers(-1, f, size=(p, cap)).astype(np.int32)
    if integer_grads:
        g = rng.integers(-8, 9, size=(p, cap)).astype(np.float32)
    else:
        g = rng.normal(size=(p, cap)).astype(np.float32)
    g = np.where(ids >= 0, g, 0.0).astype(np.float32)
    return jnp.asarray(ids), jnp.asarray(g)


@pytest.mark.parametrize("p,cap,f,base", [
    (4, 16, 64, 0), (8, 32, 64, 16), (1, 64, 256, 0), (3, 10, 32, 8),
])
def test_owner_accumulate_integer_bit_exact(p, cap, f, base):
    """Integer-valued grads: every per-feature total is exactly
    representable, so reassociating the in-run addition order (matmul
    run totals vs scatter order) cannot change a bit — the kernel path
    must equal the XLA scatter-add exactly. This also proves the SET of
    addends per feature is identical."""
    ids, g = _routing_case(p, cap, f, seed=p + cap, integer_grads=True)
    acc = jnp.zeros((f,), jnp.float32)
    r0 = ops.owner_accumulate(ids, g, acc, base, impl="xla")
    r1 = ops.owner_accumulate(ids, g, acc, base, impl="pallas_interpret",
                              block=16)
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))


def test_owner_accumulate_float_tolerance():
    """General f32: in-run addition order differs between the two paths
    (documented at ops.owner_accumulate), so the contract is allclose at
    LSB-level tolerance, not bit equality."""
    ids, g = _routing_case(8, 64, 128, seed=7)
    acc = jnp.zeros((128,), jnp.float32)
    r0 = ops.owner_accumulate(ids, g, acc, 0, impl="xla")
    r1 = ops.owner_accumulate(ids, g, acc, 0, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1),
                               rtol=1e-6, atol=1e-6)


def test_owner_accumulate_edge_shapes():
    """All-padding input is a no-op; all-one-feature input concentrates
    every add into one accumulator slot (the run spans many blocks)."""
    f = 32
    acc0 = jnp.arange(f, dtype=jnp.float32)       # non-zero start
    all_pad = jnp.full((4, 16), -1, jnp.int32)
    g = jnp.zeros((4, 16), jnp.float32)
    out = ops.owner_accumulate(all_pad, g, acc0, 0,
                               impl="pallas_interpret", block=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(acc0))
    one_id = jnp.full((4, 16), 5, jnp.int32)
    ones = jnp.ones((4, 16), jnp.float32)
    out = ops.owner_accumulate(one_id, ones, jnp.zeros((f,)), 0,
                               impl="pallas_interpret", block=8)
    want = np.zeros((f,), np.float32)
    want[5] = 64.0
    np.testing.assert_array_equal(np.asarray(out), want)


def test_owner_accumulate_base_offset_drop():
    """Features above this owner's [base, base+block) window and padding
    are dropped by mode="drop" on both paths. (Below-base ids cannot
    occur: route_build routes each id to its owner by id // block, so a
    received buffer only ever holds in-window ids and padding.)"""
    ids = jnp.asarray([[17, 18, 31, -1, 40]], jnp.int32)
    g = jnp.asarray([[2.0, 3.0, 4.0, 9.0, 5.0]], jnp.float32)
    acc = jnp.zeros((16,), jnp.float32)           # owner block [16, 32)
    r0 = ops.owner_accumulate(ids, g, acc, 16, impl="xla")
    r1 = ops.owner_accumulate(ids, g, acc, 16, impl="pallas_interpret",
                              block=4)
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
    want = np.zeros((16,), np.float32)
    want[1], want[2], want[15] = 2.0, 3.0, 4.0
    np.testing.assert_array_equal(np.asarray(r0), want)


def test_owner_accumulate_routing_path_parity():
    """Against the REAL routing layout: route_build's request buffer ids
    (ascending unique per row, -1 tail) through both impls — the shape
    the strategies actually feed the seam."""
    from repro.core import sparse

    p, block, cap, f = 4, 16, 12, 64
    rng = np.random.default_rng(11)
    flat = jnp.asarray(rng.integers(-1, f, size=(48,)).astype(np.int32))
    routing = sparse.route_build(flat, p, block, cap)
    g = jnp.where(routing.req_ids >= 0,
                  jnp.asarray(rng.integers(-4, 5,
                                           size=(p, cap)).astype(np.float32)),
                  0.0)
    for base in (0, 16):
        r0 = ops.owner_accumulate(routing.req_ids, g,
                                  jnp.zeros((block,)), base, impl="xla")
        r1 = ops.owner_accumulate(routing.req_ids, g,
                                  jnp.zeros((block,)), base,
                                  impl="pallas_interpret", block=8)
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))


# ---------------------------------------------------------------------------
# the seam end to end: StepFns parity and strategy-contract conformance
# ---------------------------------------------------------------------------


def test_step_fns_parity_single_device():
    """topk_reduce train steps on a 1-device mesh: kernel_impl
    "pallas_interpret" (select_pack + owner_accumulate kernels live) is
    bit-identical to "xla" — params AND the error-feedback carry."""
    from repro import compat
    from repro.configs.base import DPMRConfig
    from repro.core import dpmr
    from repro.launch.mesh import make_host_mesh

    cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8,
                     distribution="topk_reduce", topk_frac=0.25)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    b = 32
    ids = rng.integers(-1, cfg.num_features, size=(b, 8)).astype(np.int32)
    vals = np.where(ids >= 0, rng.normal(size=(b, 8)), 0.0).astype(
        np.float32)
    batch = {"ids": jnp.asarray(ids), "vals": jnp.asarray(vals),
             "labels": jnp.asarray(
                 rng.integers(0, 2, size=(b,)).astype(np.int32))}
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        with compat.set_mesh(mesh):
            fns = dpmr.make_step_fns(cfg, mesh, b, kernel_impl=impl)
            st = dpmr.init_state(cfg, mesh)
            for _ in range(3):
                st, _ = fns.train_step(st, batch)
        outs[impl] = (np.asarray(st.cold), np.asarray(st.strat))
    for a, b_ in zip(outs["xla"], outs["pallas_interpret"]):
        np.testing.assert_array_equal(a, b_)


@pytest.mark.slow
def test_step_fns_parity_multidevice():
    """The same parity on a real 4-shard exchange (subprocess, emulated
    devices): the kernels sit between unchanged collectives, so every
    strategy that routes through the seam stays bit-identical."""
    import json
    import os
    import pathlib
    import subprocess
    import sys

    repo = pathlib.Path(__file__).resolve().parents[1]
    body = """
import json
import numpy as np
import jax.numpy as jnp
from repro import compat
from repro.configs.base import DPMRConfig
from repro.core import dpmr
from repro.launch.mesh import make_host_mesh

out = {}
for dist in ("a2a", "topk_reduce"):
    cfg = DPMRConfig(num_features=1 << 10, max_features_per_sample=8,
                     distribution=dist, topk_frac=0.25)
    mesh = make_host_mesh(4, 1)
    rng = np.random.default_rng(0)
    b = 64
    ids = rng.integers(-1, cfg.num_features, size=(b, 8)).astype(np.int32)
    vals = np.where(ids >= 0, rng.normal(size=(b, 8)), 0.0).astype(
        np.float32)
    batch = {"ids": jnp.asarray(ids), "vals": jnp.asarray(vals),
             "labels": jnp.asarray(
                 rng.integers(0, 2, size=(b,)).astype(np.int32))}
    res = {}
    for impl in ("xla", "pallas_interpret"):
        with compat.set_mesh(mesh):
            fns = dpmr.make_step_fns(cfg, mesh, b, kernel_impl=impl)
            st = dpmr.init_state(cfg, mesh)
            for _ in range(3):
                st, _ = fns.train_step(st, batch)
        res[impl] = (np.asarray(st.cold), np.asarray(st.strat))
    out[dist] = {
        "cold_equal": bool(np.array_equal(res["xla"][0],
                                          res["pallas_interpret"][0])),
        "carry_equal": bool(np.array_equal(res["xla"][1],
                                           res["pallas_interpret"][1])),
        "cold_moved": bool(np.abs(res["xla"][0]).max() > 0),
    }
print(json.dumps(out))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for dist, r in out.items():
        assert r["cold_equal"] and r["carry_equal"], (dist, r)
        assert r["cold_moved"], (dist, r)


def test_pallas_impl_keeps_audit_green():
    """The strategy contract audit on kernel_impl="pallas" contexts: the
    kernels change lowering, never the collectives, so every analytic
    rule (W-MATCH, E-WIRE's declared-vs-traced wire, carry lifecycle)
    must stay green with the pallas path selected."""
    from repro.analysis import audit_registry, build_contexts

    contexts = tuple(
        actx._replace(ctx=actx.ctx._replace(kernel_impl="pallas"))
        for actx in build_contexts())
    report = audit_registry(contexts=contexts, engine_checks=False)
    assert report["ok"], [
        f for s in report["strategies"].values()
        for geo in s.values() if isinstance(geo, dict)
        for f in geo.get("findings", [])]
