"""Documentation tests: the strategy-authoring guide's code is executed
(doctest-style — the worked `register_strategy` example must actually
register and train), and every code path referenced from docs/*.md must
exist (the same link-check scripts/check.sh runs)."""
import pathlib
import re
import subprocess
import sys

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
STRATEGIES_DOC = ROOT / "docs" / "strategies.md"
ARCHITECTURE_DOC = ROOT / "docs" / "ARCHITECTURE.md"
KERNELS_DOC = ROOT / "docs" / "KERNELS.md"
DISTRIBUTED_DOC = ROOT / "docs" / "DISTRIBUTED.md"


def _python_blocks(path: pathlib.Path):
    return re.findall(r"```python\n(.*?)```", path.read_text(), re.S)


def test_docs_exist_and_name_the_contract():
    assert STRATEGIES_DOC.exists() and ARCHITECTURE_DOC.exists()
    text = STRATEGIES_DOC.read_text()
    # the load-bearing pieces of the authoring surface must be documented
    for needle in ("DistributionStrategy", "bytes_per_device", "WireBytes",
                   "register_strategy", "StrategyContext", "init_carry",
                   "outer_axes"):
        assert needle in text, f"strategies.md lost its {needle} section"
    arch = ARCHITECTURE_DOC.read_text()
    for needle in ("pod", "data", "model", "invertDocuments",
                   "distributeParameters", "repro/data", "engine.py"):
        assert needle in arch, f"ARCHITECTURE.md lost its {needle} entry"


def test_strategies_guide_example_runs():
    """Every ```python block in docs/strategies.md executes top to bottom
    in one namespace: the worked example registers a strategy, trains
    through it, and queries its two-tier wire model. A doc edit that
    breaks the example breaks this test."""
    blocks = _python_blocks(STRATEGIES_DOC)
    assert len(blocks) >= 3, "the worked example lost its code blocks"
    ns = {}
    for i, block in enumerate(blocks):
        exec(compile(block, f"{STRATEGIES_DOC}#block{i}", "exec"), ns)
    # the guide promises a trained history and a two-tier wire figure
    assert np.isfinite(ns["history"][-1]["loss"])
    assert ns["wire"].total == ns["wire"].inner + ns["wire"].outer
    from repro.api import list_strategies
    assert "doc_rowcast" in list_strategies()


def test_kernels_guide_names_the_contract():
    assert KERNELS_DOC.exists()
    text = KERNELS_DOC.read_text()
    # the load-bearing pieces of the kernel-authoring surface
    for needle in ("kernel_impl", "BlockSpec", "interpret", "MAX_CAPACITY",
                   "normalize_impl", "broadcasted_iota", "topk_count",
                   "bit-exact", "scratch"):
        assert needle in text, f"KERNELS.md lost its {needle} section"


def test_kernels_guide_example_runs():
    """Every ```python block in docs/KERNELS.md executes top to bottom in
    one namespace: the minimal kernel runs in interpret mode on CPU and
    its parity check against the jnp oracle passes. A doc edit that
    breaks the worked example breaks this test."""
    blocks = _python_blocks(KERNELS_DOC)
    assert len(blocks) >= 2, "the kernel guide lost its code blocks"
    ns = {}
    for i, block in enumerate(blocks):
        exec(compile(block, f"{KERNELS_DOC}#block{i}", "exec"), ns)
    assert ns["kernel_demo_ok"] is True


def test_distributed_guide_names_the_contract():
    assert DISTRIBUTED_DOC.exists()
    text = DISTRIBUTED_DOC.read_text()
    # the load-bearing pieces of the multi-process operating surface
    for needle in ("--coordinator", "--num-processes", "--process-id",
                   "make_array_from_process_local_data", "global_rows",
                   "manifest.json", "os.replace", "completeness marker",
                   "host_value", "reshard_dpmr_state", "pmean",
                   "wait_saves"):
        assert needle in text, f"DISTRIBUTED.md lost its {needle} section"


def test_distributed_guide_example_runs():
    """Every ```python block in docs/DISTRIBUTED.md executes top to
    bottom in one namespace: the all-hosts emulation demo reproduces the
    stride union, and the async save/restore demo round-trips through a
    real checkpoint directory. A doc edit that breaks either breaks this
    test."""
    blocks = _python_blocks(DISTRIBUTED_DOC)
    assert len(blocks) >= 2, "the distributed guide lost its code blocks"
    ns = {}
    for i, block in enumerate(blocks):
        exec(compile(block, f"{DISTRIBUTED_DOC}#block{i}", "exec"), ns)
    assert ns["distributed_demo_ok"] is True


def test_docs_link_check_passes():
    """scripts/check_docs.py (also wired into scripts/check.sh) finds no
    dangling file or module reference in docs/*.md."""
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
