"""`core/hot_sharding.py` unit tests + serving hot-cache correctness.

The hot-sharding primitives (feature_counts / select_hot / split_hot /
load_imbalance) were consumer-less until the serving subsystem; this file
pins their semantics directly, then asserts the serving-facing contract of
`repro.serve.hot_cache`: a cached hit is BIT-IDENTICAL to the uncached
sparse predict while the mirror is fresh, and the staleness bound forces a
refresh (never serving stale parameter values after training moved on).
"""
import jax.numpy as jnp
import numpy as np

from repro.api import DPMREngine, hot_ids_from_corpus
from repro.configs.base import DPMRConfig
from repro.core import hot_sharding
from repro.data import get_source
from repro.launch.mesh import make_host_mesh
from repro.serve import HotCacheConfig, HotFeatureCache, ServeMetrics

INT_MAX = hot_sharding.INT_MAX
F = 1 << 10


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_feature_counts_histogram():
    ids = jnp.asarray([[0, 1, 1], [2, -1, 1]], jnp.int32)
    counts = np.asarray(hot_sharding.feature_counts(ids, 4))
    assert counts.tolist() == [1, 3, 1, 0]


def test_feature_counts_drops_padding_only():
    ids = jnp.asarray([-1, -1, 3], jnp.int32)
    counts = np.asarray(hot_sharding.feature_counts(ids, 4))
    assert counts.sum() == 1 and counts[3] == 1


def test_feature_counts_any_shape():
    flat = jnp.arange(6, dtype=jnp.int32)
    assert np.array_equal(
        np.asarray(hot_sharding.feature_counts(flat, 8)),
        np.asarray(hot_sharding.feature_counts(flat.reshape(2, 3), 8)))


def test_select_hot_threshold_and_sorting():
    counts = jnp.asarray([10, 0, 5, 1], jnp.int32)    # total 16
    ids = np.asarray(hot_sharding.select_hot(counts, 0.3, 3))
    # freq >= 0.3 keeps features 0 (0.625) and 2 (0.3125) only
    assert ids.tolist() == [0, 2, INT_MAX]


def test_select_hot_max_hot_cap():
    counts = jnp.asarray([4, 3, 2, 1], jnp.int32)
    ids = np.asarray(hot_sharding.select_hot(counts, 0.0, 2))
    assert ids.tolist() == [0, 1]        # two largest counts, sorted


def test_select_hot_zero_count_never_selected():
    counts = jnp.zeros((4,), jnp.int32).at[1].set(2)
    ids = np.asarray(hot_sharding.select_hot(counts, 0.0, 4))
    assert ids.tolist() == [1, INT_MAX, INT_MAX, INT_MAX]


def test_select_hot_nothing_eligible():
    counts = jnp.asarray([1, 1], jnp.int32)
    ids = np.asarray(hot_sharding.select_hot(counts, 0.9, 2))
    assert ids.tolist() == [INT_MAX, INT_MAX]


def test_split_hot_partition():
    hot_ids = jnp.asarray([2, 5] + [INT_MAX] * 2, jnp.int32)
    flat = jnp.asarray([2, 3, 5, -1], jnp.int32)
    slot, is_hot, cold = (np.asarray(a) for a in
                          hot_sharding.split_hot(flat, hot_ids))
    assert is_hot.tolist() == [True, False, True, False]
    assert slot.tolist() == [0, -1, 1, -1]
    assert cold.tolist() == [-1, 3, -1, -1]


def test_split_hot_roundtrips_every_id():
    # every input id is either hot (slot >= 0) or cold (cold >= 0) or
    # padding — never two of the three
    hot_ids = jnp.asarray([1, 4, 7, INT_MAX], jnp.int32)
    flat = jnp.asarray([0, 1, 2, 4, 6, 7, -1, 9], jnp.int32)
    slot, is_hot, cold = (np.asarray(a) for a in
                          hot_sharding.split_hot(flat, hot_ids))
    for i, f in enumerate(np.asarray(flat)):
        if f < 0:
            assert not is_hot[i] and cold[i] == -1
        elif is_hot[i]:
            assert cold[i] == -1 and np.asarray(hot_ids)[slot[i]] == f
        else:
            assert cold[i] == f and slot[i] == -1


def test_load_imbalance_uniform_vs_skewed():
    # 4 shards x block 2: one id per owner -> perfectly balanced
    even = jnp.asarray([0, 2, 4, 6], jnp.int32)
    assert float(hot_sharding.load_imbalance(even, 4, 2)) == 1.0
    # all ids on owner 0 -> max/mean = num_shards
    skew = jnp.asarray([0, 1, 0, 1], jnp.int32)
    assert float(hot_sharding.load_imbalance(skew, 4, 2)) == 4.0


def test_load_imbalance_ignores_padding():
    ids = jnp.asarray([0, 2, 4, 6, -1, -1], jnp.int32)
    assert float(hot_sharding.load_imbalance(ids, 4, 2)) == 1.0


# ---------------------------------------------------------------------------
# serving hot cache
# ---------------------------------------------------------------------------


def _trained_engine(max_hot=16, steps=8):
    mesh = make_host_mesh(1, 1)
    cfg = DPMRConfig(num_features=F, max_features_per_sample=8,
                     max_hot=max_hot, hot_threshold=0.001)
    src = get_source("zipf_sparse", batch_size=8, num_batches=8,
                     num_features=F, features_per_sample=8, seed=3)
    # a real model-hot set, so the cache mirror must gather from BOTH the
    # replicated hot table and the sharded cold table
    hot = hot_ids_from_corpus(cfg, src.iter_batches(limit=4), mesh)
    eng = DPMREngine(cfg, mesh, hot_ids=hot)
    eng.fit_sgd(src.iter_batches(), steps=steps)
    return eng, src


def _request(src, i):
    b = src.batch(i)
    return b["ids"], b["vals"]


def test_cached_hit_bit_identical_to_sparse_path():
    eng, src = _trained_engine()
    cache = HotFeatureCache(eng, HotCacheConfig(max_hot=64, threshold=0.0,
                                                window=64,
                                                refresh_every=1000),
                            ServeMetrics())
    ids, vals = _request(src, 0)
    # make every feature of the request window-hot (threshold 0 selects
    # anything observed; 64 slots cover the <=64 distinct ids)
    cache.observe(ids)
    got = cache.lookup(ids, vals)
    assert got is not None, "fully-observed request must hit"
    ref = eng.predict({"ids": ids, "vals": vals})
    np.testing.assert_array_equal(got, ref)   # bit-exact, not approx
    assert cache.metrics.snapshot()["cache_hits"] == 1


def test_unseen_feature_misses():
    eng, src = _trained_engine()
    cache = HotFeatureCache(eng, HotCacheConfig(max_hot=64, threshold=0.0,
                                                window=64,
                                                refresh_every=1000),
                            ServeMetrics())
    ids, vals = _request(src, 0)
    cache.observe(ids)
    cache.lookup(ids, vals)                   # builds the mirror
    other = np.full_like(ids, -1)
    other[0, 0] = (int(ids.max()) + 1) % F    # a feature never observed
    assert cache.lookup(other, vals) is None
    assert cache.metrics.snapshot()["cache_misses"] == 1


def test_staleness_bound_forces_refresh():
    eng, src = _trained_engine()
    cache = HotFeatureCache(eng, HotCacheConfig(max_hot=64, threshold=0.0,
                                                window=64, refresh_every=3),
                            ServeMetrics())
    ids, vals = _request(src, 0)
    cache.observe(ids)
    for _ in range(7):
        assert cache.lookup(ids, vals) is not None
    m = cache.metrics.snapshot()
    # 7 lookups at refresh_every=3: initial gather + 2 staleness refreshes
    assert m["cache_refreshes"] == 3
    assert m["cache_stale_refreshes"] == 2
    assert cache.staleness == 1               # one lookup since the last


def test_step_change_refreshes_and_tracks_new_params():
    eng, src = _trained_engine()
    cache = HotFeatureCache(eng, HotCacheConfig(max_hot=64, threshold=0.0,
                                                window=64,
                                                refresh_every=1000),
                            ServeMetrics())
    ids, vals = _request(src, 0)
    cache.observe(ids)
    before = cache.lookup(ids, vals)
    assert before is not None
    # training moves the resident parameters; the mirror must notice the
    # step change and re-gather BEFORE answering, not serve stale values
    eng.fit_sgd(src.iter_batches(), steps=4)
    after = cache.lookup(ids, vals)
    assert after is not None
    m = cache.metrics.snapshot()
    assert m["cache_step_refreshes"] == 1
    assert not np.array_equal(before, after), "params moved; so must probs"
    np.testing.assert_array_equal(after,
                                  eng.predict({"ids": ids, "vals": vals}))


def test_window_eviction_drops_old_features():
    eng, src = _trained_engine()
    cache = HotFeatureCache(eng, HotCacheConfig(max_hot=64, threshold=0.0,
                                                window=2, refresh_every=1),
                            ServeMetrics())
    ids0, vals0 = _request(src, 0)
    ids1, vals1 = _request(src, 1)
    cache.observe(ids0)
    assert cache.lookup(ids0, vals0) is not None
    # push two newer requests through a window of 2: ids0 falls out
    cache.observe(ids1)
    cache.observe(ids1)
    only0 = set(np.unique(ids0[ids0 >= 0])) - set(np.unique(ids1[ids1 >= 0]))
    if only0:    # zipf heads may overlap entirely; only assert when not
        assert cache.lookup(ids0, vals0) is None


def test_empty_window_never_hits():
    eng, src = _trained_engine()
    cache = HotFeatureCache(eng, HotCacheConfig(max_hot=8, threshold=0.0,
                                                window=4, refresh_every=10),
                            ServeMetrics())
    ids, vals = _request(src, 0)
    assert cache.lookup(ids, vals) is None    # nothing observed yet
