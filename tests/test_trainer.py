"""Trainer invariants: microbatch equivalence, clipping, schedules, AdamW."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data.pipeline import LMDataConfig, LMDataset
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.optim import optimizers, schedules
from repro.train import trainer


def _setup(arch="yi-6b", micro=1, opt="adamw"):
    cfg = registry.smoke_config(arch)
    spec = registry.get_spec(arch)
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=100,
                     optimizer=opt, grad_clip=1.0)
    pc = ParallelConfig(microbatches=micro)
    mesh = make_host_mesh(1, 1)
    return cfg, spec, tc, pc, mesh


def test_microbatch_equivalence():
    """k=1 and k=4 grad accumulation produce the same update."""
    outs = {}
    for k in (1, 4):
        cfg, spec, tc, pc, mesh = _setup(micro=k)
        with compat.set_mesh(mesh):
            state = trainer.init_state(spec, cfg, tc, pc,
                                       jax.random.PRNGKey(0))
            step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
            ds = LMDataset(LMDataConfig(cfg.vocab_size, 16, 8))
            state, m = step(state, jax.tree.map(jnp.asarray, ds.batch(0)))
        outs[k] = (np.asarray(
            jax.tree.leaves(state["params"])[0]), float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-5)
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=2e-4, atol=2e-6)


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0), "b": jnp.full((10,), -100.0)}
    clipped, norm = optimizers.clip_by_global_norm(g, 1.0)
    assert float(norm) > 400
    cn = optimizers.global_norm(clipped)
    np.testing.assert_allclose(float(cn), 1.0, rtol=1e-5)


def test_warmup_cosine_schedule():
    fn = schedules.warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(fn(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(fn(jnp.int32(10))), 1.0, rtol=1e-6)
    assert float(fn(jnp.int32(55))) < 1.0
    np.testing.assert_allclose(float(fn(jnp.int32(100))), 0.1, rtol=1e-5)


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.01, beta1=0.9,
                     beta2=0.999)
    opt = optimizers.get_optimizer("adamw")
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = opt.init(p, "float32")
    new_p, new_state = opt.update(g, state, p, 0.1, tc)

    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    want = np.asarray(p["w"]) - 0.1 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_state["count"]) == 1


def test_sgd_and_momentum_update_directions():
    tc = TrainConfig(learning_rate=1.0, beta1=0.9)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.ones((4,))}
    for name in ("sgd", "momentum"):
        opt = optimizers.get_optimizer(name)
        st = opt.init(p, "float32")
        np_, _ = opt.update(g, st, p, 0.5, tc)
        assert float(np_["w"][0]) < 1.0


def test_deterministic_data_pipeline():
    ds = LMDataset(LMDataConfig(100, 8, 4, seed=3))
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_loss_decreases_over_training():
    cfg, spec, tc, pc, mesh = _setup(arch="granite-8b")
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        ds = LMDataset(LMDataConfig(cfg.vocab_size, 32, 8))
        losses = []
        for i in range(25):
            state, m = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
            losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
