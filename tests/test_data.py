"""Data-plane tests: source registry, built-in source equivalence, file
corpus roundtrip, ShardedLoader (conformance, host sharding, prefetch,
cursors), and resume-exactness through engine save/restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DPMREngine
from repro.configs.base import DPMRConfig
from repro.data import (Cursor, DataSource, ShardedLoader, get_source,
                        list_sources, register_source, sparse_corpus,
                        write_file_corpus)
from repro.data.pipeline import LMDataConfig, LMDataset
from repro.launch.mesh import make_host_mesh

F = 1 << 12
CORPUS = dict(num_features=F, features_per_sample=16, signal_features=256,
              seed=0)


def _zipf(batch_size=64, num_batches=None, start=0):
    return get_source("zipf_sparse", batch_size=batch_size,
                      num_batches=num_batches, start=start, **CORPUS)


def _cfg(**kw):
    base = dict(num_features=F, max_features_per_sample=16, iterations=2,
                learning_rate=1.0, max_hot=32, optimizer="adagrad")
    base.update(kw)
    return DPMRConfig(**base)


def _assert_batches_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_source_registry():
    assert {"zipf_sparse", "lm_markov", "file_sparse"} <= set(list_sources())
    with pytest.raises(KeyError):
        get_source("nope")

    @register_source("test_custom_source")
    class Custom(DataSource):
        name = "test_custom_source"
        batch_size = 4
        num_batches = 2

        def batch(self, index):
            self._check_index(index)
            return {"x": np.full((4,), index)}

    src = get_source("test_custom_source")
    assert src.batch(1)["x"][0] == 1
    with pytest.raises(IndexError):
        src.batch(2)
    assert len(list(src.iter_batches())) == 2


# ---------------------------------------------------------------------------
# built-in sources honour the documented seeding contract; the one-release
# deprecation shims over the loose generators are GONE
# ---------------------------------------------------------------------------


def test_zipf_source_seeding_contract():
    """`zipf_sparse.batch(i)` == `make_batch(spec, bs, batch_seed(spec,
    start + i))` — the per-index seeding rule checkpoint resume-exactness
    rests on."""
    src = _zipf(num_batches=5)
    spec = src.spec
    for i in (0, 2, 4):
        _assert_batches_equal(
            src.batch(i),
            sparse_corpus.make_batch(spec, 64,
                                     sparse_corpus.batch_seed(spec, i)))
    # start= carves a held-out window out of the same index space
    tail = get_source("zipf_sparse", spec=spec, batch_size=64,
                      num_batches=2, start=3)
    _assert_batches_equal(tail.batch(0), src.batch(3))
    _assert_batches_equal(tail.batch(1), src.batch(4))


def test_lm_source_matches_dataset():
    src = get_source("lm_markov", vocab_size=101, seq_len=8, batch_size=4,
                     seed=3)
    ds = LMDataset(LMDataConfig(101, 8, 4, seed=3))
    for i in (0, 7):
        _assert_batches_equal(src.batch(i), ds.batch(i))
    enc = get_source("lm_markov", vocab_size=101, seq_len=8, batch_size=4,
                     encdec_d_model=16)
    assert enc.batch(0)["frames"].shape == (4, 8, 16)


def test_legacy_generator_shims_removed():
    """`sparse_corpus.batches` / `LMDataset.iterate` finished their
    one-release deprecation (migration table in CHANGES.md)."""
    assert not hasattr(sparse_corpus, "batches")
    assert not hasattr(LMDataset, "iterate")
    assert not hasattr(LMDataset(LMDataConfig(11, 4, 2)), "iterate")


# ---------------------------------------------------------------------------
# file_sparse: the on-disk sample shards
# ---------------------------------------------------------------------------


def test_file_corpus_roundtrip(tmp_path):
    src = _zipf(num_batches=6)
    manifest = write_file_corpus(str(tmp_path), src, batches_per_chunk=4)
    assert manifest["num_chunks"] == 2
    fs = get_source("file_sparse", directory=str(tmp_path))
    assert fs.num_batches == 6 and fs.batch_size == 64
    for i in (0, 3, 5, 1):                        # includes a backward seek
        _assert_batches_equal(fs.batch(i), src.batch(i))
    with pytest.raises(IndexError):
        fs.batch(6)
    # batches are copies: consumer mutation must not corrupt the cache
    fs.batch(0)["vals"][:] = -99.0
    _assert_batches_equal(fs.batch(0), src.batch(0))


def test_file_source_shared_across_prefetch_threads(tmp_path):
    """One FileSparseSource object served to two prefetching loaders at
    once: the chunk cache is locked, so neither stream sees torn or
    wrong-chunk batches."""
    src = _zipf(num_batches=8)
    write_file_corpus(str(tmp_path), src, batches_per_chunk=2)
    shared = get_source("file_sparse", directory=str(tmp_path))
    la = ShardedLoader(shared, placement="host", prefetch=2)
    lb = ShardedLoader(shared, placement="host", prefetch=2,
                       cursor=Cursor(0, 5))
    ita, itb = la.batches(8), lb.batches(3)
    got_a, got_b = [], []
    for i in range(8):                  # interleave: both threads live
        got_a.append(next(ita))
        if i < 3:
            got_b.append(next(itb))
    for i in range(8):
        _assert_batches_equal(got_a[i], src.batch(i))
    for j in range(3):
        _assert_batches_equal(got_b[j], src.batch(5 + j))


def test_write_file_corpus_unbounded_needs_count(tmp_path):
    with pytest.raises(ValueError):
        write_file_corpus(str(tmp_path), _zipf(num_batches=None))
    write_file_corpus(str(tmp_path), _zipf(num_batches=None), num_batches=3)
    assert get_source("file_sparse", directory=str(tmp_path)).num_batches == 3


# ---------------------------------------------------------------------------
# ShardedLoader: conformance, sharding, prefetch, cursor
# ---------------------------------------------------------------------------


def test_loader_epoch_rollover_and_seek():
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(num_batches=3), mesh, prefetch=0)
    got = loader.take(5)                    # epoch 0 (3 batches) + 2 more
    assert loader.cursor == Cursor(1, 2)
    _assert_batches_equal(got[3], got[0])   # epochs re-read the same shard
    fresh = ShardedLoader(_zipf(num_batches=3), mesh, prefetch=0)
    fresh.seek(Cursor(1, 1))
    _assert_batches_equal(fresh.take(1)[0], got[4])


def test_loader_prefetch_stream_identical():
    mesh = make_host_mesh(1, 1)
    sync = ShardedLoader(_zipf(num_batches=4), mesh, prefetch=0).take(7)
    pre = ShardedLoader(_zipf(num_batches=4), mesh, prefetch=3).take(7)
    for a, b in zip(sync, pre, strict=True):
        _assert_batches_equal(a, b)


def test_loader_early_break_cursor_and_thread():
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(), mesh, prefetch=2)
    for i, _ in enumerate(loader.batches()):      # unbounded stream
        if i == 2:
            break
    assert loader.cursor == Cursor(0, 3)          # 3 batches consumed
    # the stream resumes exactly where the consumer stopped
    _assert_batches_equal(loader.take(1)[0],
                          ShardedLoader(_zipf(), mesh,
                                        cursor=Cursor(0, 3)).take(1)[0])


def test_loader_host_sharding():
    mesh = make_host_mesh(1, 1)
    src = _zipf(num_batches=6)
    h0 = ShardedLoader(src, mesh, host_index=0, num_hosts=2, prefetch=0)
    h1 = ShardedLoader(_zipf(num_batches=6), mesh, host_index=1, num_hosts=2,
                       prefetch=0)
    assert h0.steps_per_epoch == 3                # 6 batches // 2 hosts
    _assert_batches_equal(h0.take(1)[0], src.batch(0))
    _assert_batches_equal(h1.take(1)[0], src.batch(1))
    _assert_batches_equal(h1.take(1)[0], src.batch(3))


def test_loader_conform_drop_and_pad():
    mesh = make_host_mesh(1, 1)
    drop = ShardedLoader(_zipf(), mesh, batch_divisor=48, prefetch=0)
    assert next(iter(drop.batches(1)))["ids"].shape[0] == 48
    pad = ShardedLoader(_zipf(), mesh, batch_divisor=48, remainder="pad",
                        prefetch=0)
    b = next(iter(pad.batches(1)))
    assert b["ids"].shape[0] == 96
    tail = np.asarray(b["ids"])[64:]
    assert np.all(tail == -1)                     # empty CSR slots
    assert np.all(np.asarray(b["labels"])[64:] == 0)


def test_seek_invalidates_live_iterator():
    """Repositioning while an iterator is active raises instead of silently
    serving the stale plan and clobbering the new cursor."""
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(num_batches=8), mesh, prefetch=0)
    it = loader.batches(4)
    next(it)
    loader.seek(Cursor(0, 6))
    with pytest.raises(RuntimeError, match="repositioned"):
        next(it)
    _assert_batches_equal(loader.take(1)[0],
                          _zipf(num_batches=8).batch(6))  # seek honored


def test_second_iterator_invalidates_first():
    """Two live iterators over one loader would double-serve prefetched
    positions; starting the second stales the first."""
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(num_batches=8), mesh, prefetch=2)
    it1 = loader.batches()
    b0 = next(it1)
    _assert_batches_equal(b0, _zipf(num_batches=8).batch(0))
    it2 = loader.batches()
    b1 = next(it2)                          # continues from cursor (0, 1)
    _assert_batches_equal(b1, _zipf(num_batches=8).batch(1))
    with pytest.raises(RuntimeError, match="repositioned|iterator"):
        next(it1)
    assert loader.cursor == Cursor(0, 2)    # it1 could not clobber it


def test_loader_unbounded_epoch_raises():
    lm = get_source("lm_markov", vocab_size=11, seq_len=4, batch_size=2)
    loader = ShardedLoader(lm, placement="host", prefetch=0)
    with pytest.raises(ValueError, match="unbounded"):
        loader.epoch()
    bounded = ShardedLoader(lm, placement="host", prefetch=0, epoch_size=4)
    assert len(list(bounded.epoch())) == 4


def test_loader_producer_error_propagates():
    class Broken(DataSource):
        name = "broken"
        batch_size = 4
        num_batches = None

        def batch(self, index):
            if index >= 2:
                raise RuntimeError("disk on fire")
            return {"x": np.zeros((4,))}

    loader = ShardedLoader(Broken(), placement="host", prefetch=2)
    with pytest.raises(RuntimeError, match="disk on fire"):
        loader.take(5)


# ---------------------------------------------------------------------------
# per-epoch shuffling
# ---------------------------------------------------------------------------


def _batch_key(batch):
    """Hashable identity of a batch (its ids bytes) for multiset checks."""
    return np.asarray(batch["ids"]).tobytes()


def test_shuffle_permutes_each_epoch():
    """Each epoch covers exactly the source's batch set, in an order that
    differs between epochs and from the unshuffled stream."""
    mesh = make_host_mesh(1, 1)
    src = _zipf(num_batches=6)
    base_keys = [_batch_key(src.batch(i)) for i in range(6)]
    loader = ShardedLoader(_zipf(num_batches=6), mesh, prefetch=0,
                           shuffle=True)
    e0 = [_batch_key(b) for b in loader.take(6)]
    e1 = [_batch_key(b) for b in loader.take(6)]
    assert sorted(e0) == sorted(base_keys)      # same multiset...
    assert sorted(e1) == sorted(base_keys)
    assert e0 != e1                             # ...fresh order per epoch
    assert loader.cursor == Cursor(2, 0)


def test_shuffle_is_deterministic_and_seeded():
    mesh = make_host_mesh(1, 1)
    a = ShardedLoader(_zipf(num_batches=6), mesh, prefetch=0, shuffle=True)
    b = ShardedLoader(_zipf(num_batches=6), mesh, prefetch=0, shuffle=True)
    for x, y in zip(a.take(8), b.take(8), strict=True):
        _assert_batches_equal(x, y)
    fresh = ShardedLoader(_zipf(num_batches=6), mesh, prefetch=0,
                          shuffle=True)
    other = ShardedLoader(_zipf(num_batches=6), mesh, prefetch=0,
                          shuffle=True, shuffle_seed=7)
    assert [_batch_key(x) for x in other.take(6)] != \
        [_batch_key(x) for x in fresh.take(6)]


def test_shuffle_requires_bounded_epoch():
    with pytest.raises(ValueError, match="bounded"):
        ShardedLoader(_zipf(), make_host_mesh(1, 1), shuffle=True)
    # an explicit epoch_size bounds an unbounded source
    lm = get_source("lm_markov", vocab_size=11, seq_len=4, batch_size=2)
    loader = ShardedLoader(lm, placement="host", prefetch=0,
                           epoch_size=4, shuffle=True)
    assert len(list(loader.epoch())) == 4


def test_shuffle_seek_reproduces_stream():
    """The permutation is a pure function of (seed, epoch): seeking into
    the middle of any epoch reproduces the uninterrupted order."""
    mesh = make_host_mesh(1, 1)
    full = ShardedLoader(_zipf(num_batches=5), mesh, prefetch=2,
                         shuffle=True).take(12)
    jumped = ShardedLoader(_zipf(num_batches=5), mesh, prefetch=2,
                           shuffle=True)
    jumped.seek(Cursor(1, 3))
    for want, got in zip(full[8:], jumped.take(4), strict=True):
        _assert_batches_equal(want, got)


def test_shuffle_resume_exactness_zipf(tmp_path):
    """Engine + shuffled zipf_sparse loader: train, save mid-epoch,
    restore into fresh objects — the continuation is bit-identical to the
    uninterrupted run (Cursor.epoch re-seeds the permutation)."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    ckdir = str(tmp_path / "ck")

    def loader():
        return ShardedLoader(_zipf(batch_size=128, num_batches=5), mesh,
                             shuffle=True)

    full = DPMREngine(cfg, mesh)
    full_hist = full.fit_sgd(loader(), steps=8)     # crosses epoch boundary

    part = DPMREngine(cfg, mesh)
    part_hist = part.fit_sgd(loader(), steps=4)
    part.save(ckdir)

    resumed = DPMREngine(cfg, mesh)
    resumed_loader = loader()
    manifest = resumed.restore(ckdir, loader=resumed_loader)
    assert manifest["extra"]["data"]["shuffle"] is True
    assert resumed_loader.cursor == Cursor(0, 4)
    resumed_hist = resumed.fit_sgd(resumed_loader, steps=4)

    assert part_hist + resumed_hist == full_hist
    for a, b in zip(full.state, resumed.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shuffle_mismatch_warns_on_restore():
    mesh = make_host_mesh(1, 1)
    saved = ShardedLoader(_zipf(num_batches=6), mesh,
                          shuffle=True).state_dict()
    plain = ShardedLoader(_zipf(num_batches=6), mesh)
    with pytest.warns(RuntimeWarning, match="shuffle"):
        plain.load_state_dict(saved)
    # same shuffle flag but a different seed = different permutations
    other_seed = ShardedLoader(_zipf(num_batches=6), mesh, shuffle=True,
                               shuffle_seed=7)
    with pytest.warns(RuntimeWarning, match="shuffle_seed"):
        other_seed.load_state_dict(saved)


# ---------------------------------------------------------------------------
# resume-exactness: restored engine + loader == uninterrupted run
# ---------------------------------------------------------------------------


def _sparse_source(kind, tmp_path, num_batches=None):
    if kind == "zipf_sparse":
        return _zipf(batch_size=128, num_batches=num_batches)
    d = str(tmp_path / "corpus")
    write_file_corpus(d, _zipf(batch_size=128), num_batches=8)
    return get_source("file_sparse", directory=d)


@pytest.mark.parametrize("kind", ["zipf_sparse", "file_sparse"])
def test_resume_exactness_sparse(kind, tmp_path):
    """Train k steps, save, restore into a FRESH engine + loader: the
    continued run sees bit-identical batches and reproduces the
    uninterrupted run's state exactly — on the synthetic and the on-disk
    source."""
    mesh = make_host_mesh(1, 1)
    cfg = _cfg()
    ckdir = str(tmp_path / "ck")

    full = DPMREngine(cfg, mesh)
    full_hist = full.fit_sgd(
        ShardedLoader(_sparse_source(kind, tmp_path), mesh), steps=6)

    part = DPMREngine(cfg, mesh)
    part_loader = ShardedLoader(_sparse_source(kind, tmp_path), mesh)
    part_hist = part.fit_sgd(part_loader, steps=3)
    part.save(ckdir)

    resumed = DPMREngine(cfg, mesh)
    resumed_loader = ShardedLoader(_sparse_source(kind, tmp_path), mesh)
    manifest = resumed.restore(ckdir, loader=resumed_loader)
    assert manifest["extra"]["data"]["cursor"] == {"epoch": 0, "step": 3}
    assert resumed_loader.cursor == Cursor(0, 3)
    resumed_hist = resumed.fit_sgd(resumed_loader, steps=3)

    # history of the stitched run == uninterrupted history, including step
    # numbering (fit_sgd continues from the restored state.step)
    assert part_hist + resumed_hist == full_hist
    # state bit-identical
    for a, b in zip(full.state, resumed.state, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_exactness_dense_stream():
    """Dense-face data path: a restored lm_markov loader replays the exact
    continuation of the batch stream (the launch/train.py resume story)."""
    def lm_loader():
        return ShardedLoader(
            get_source("lm_markov", vocab_size=64, seq_len=8, batch_size=4,
                       seed=11), placement="host", prefetch=2)

    full = lm_loader().take(7)

    part = lm_loader()
    _ = part.take(4)
    saved = part.state_dict()                # what the ckpt extra carries

    resumed = lm_loader()
    resumed.load_state_dict(saved)
    for want, got in zip(full[4:], resumed.take(3), strict=True):
        _assert_batches_equal(want, got)


def test_engine_save_without_loader_has_no_data_extra(tmp_path):
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    eng.fit_sgd(_zipf(batch_size=128).iter_batches(limit=2))
    eng.save(str(tmp_path))
    eng2 = DPMREngine(_cfg(), mesh)
    manifest = eng2.restore(str(tmp_path))
    assert "data" not in manifest["extra"]


def test_restore_warns_when_cursor_has_no_loader(tmp_path):
    """A cursor-carrying checkpoint restored into an engine with no loader
    must not silently drop the data position."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    eng.fit_sgd(ShardedLoader(_zipf(batch_size=128), mesh), steps=2)
    eng.save(str(tmp_path))
    fresh = DPMREngine(_cfg(), mesh)
    with pytest.warns(RuntimeWarning, match="no loader is attached"):
        fresh.restore(str(tmp_path))


def test_restore_cursorless_ckpt_still_attaches_loader(tmp_path):
    """restore(dir, loader=L) on a pre-data-plane (cursor-less) checkpoint
    must attach L, so the NEXT save records the cursor (regression)."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    eng.fit_sgd(_zipf(batch_size=128).iter_batches(limit=2))  # no loader
    eng.save(str(tmp_path / "old"))

    eng2 = DPMREngine(_cfg(), mesh)
    loader = ShardedLoader(_zipf(batch_size=128), mesh)
    eng2.restore(str(tmp_path / "old"), loader=loader)
    eng2.fit_sgd(loader, steps=1)
    eng2.save(str(tmp_path / "new"))
    eng3 = DPMREngine(_cfg(), mesh)
    fresh = ShardedLoader(_zipf(batch_size=128), mesh)
    manifest = eng3.restore(str(tmp_path / "new"), loader=fresh)
    assert manifest["extra"]["data"]["cursor"] == {"epoch": 0, "step": 1}
    assert fresh.cursor == Cursor(0, 1)


def test_epoch_generator_binds_at_iteration_time():
    """Consuming batches between epoch() and its iteration must not spill
    the pass across an epoch boundary (regression: stale batch limit)."""
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(num_batches=4), mesh, prefetch=0)
    gen = loader.epoch()
    loader.take(1)                          # cursor moves to (0, 1)
    got = list(gen)
    assert len(got) == 3                    # remainder of epoch 0 only
    assert loader.cursor == Cursor(1, 0)    # ends exactly at the boundary


def test_spec_with_non_name_data_raises():
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    with pytest.raises(TypeError, match="source NAME"):
        eng.fit_sgd(_zipf(batch_size=128), steps=1,
                    spec=dict(batch_size=64))


# ---------------------------------------------------------------------------
# engine x data-plane surface
# ---------------------------------------------------------------------------


def test_engine_accepts_source_name_and_spec():
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    hist = eng.fit_sgd("zipf_sparse", steps=2,
                       spec=dict(batch_size=128, **CORPUS))
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    assert eng._loader.cursor == Cursor(0, 2)


def test_engine_fit_and_evaluate_with_loaders():
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    train = ShardedLoader(_zipf(batch_size=128, num_batches=3), mesh)
    test = ShardedLoader(_zipf(batch_size=128, num_batches=2, start=50),
                         mesh)
    hist = eng.fit(train)
    assert len(hist) == 2                   # cfg.iterations
    assert train.cursor == Cursor(2, 0)     # one epoch per iteration
    m1 = eng.evaluate(test)
    m2 = eng.evaluate(test)                 # evaluate rewinds: repeatable
    assert m1 == m2 and 0.0 <= m1["f_avg"] <= 1.0
    assert test.cursor == Cursor(0, 0)      # cursor untouched by evaluate


def test_evaluate_does_not_move_training_cursor():
    """Evaluating on the training loader mid-run (train-set metrics) must
    not corrupt the resume position save() persists (regression)."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    loader = ShardedLoader(_zipf(batch_size=128, num_batches=5), mesh)
    eng.fit_sgd(loader, steps=3)
    assert loader.cursor == Cursor(0, 3)
    eng.evaluate(loader)                    # scores the full current epoch
    assert loader.cursor == Cursor(0, 3)    # position preserved


def test_engine_accepts_duck_typed_registered_source(tmp_path):
    """register_source only requires batch/batch_size/num_batches — a
    registered class that skips the DataSource base (and even `name`) must
    still route through the loader path (regression: the name string was
    iterated) and checkpoint (regression: state_dict read source.name)."""
    @register_source("test_duck_source")
    class Duck:                                   # no DataSource base
        batch_size = 128
        num_batches = 2

        def batch(self, index):
            return get_source("zipf_sparse", batch_size=128,
                              **CORPUS).batch(index)

    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    hist = eng.fit_sgd("test_duck_source", steps=2)
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    hist = eng.fit_sgd(Duck(), steps=1)           # instance form too
    assert len(hist) == 1
    eng.save(str(tmp_path))                       # cursor extra: class name
    assert eng._loader.state_dict()["source"] == "Duck"


def test_fit_sgd_bounded_loader_default_steps_is_one_epoch():
    """steps=None on a bounded loader == one corpus pass, not an infinite
    epoch-rollover loop (regression); unbounded without steps is an error."""
    mesh = make_host_mesh(1, 1)
    eng = DPMREngine(_cfg(), mesh)
    loader = ShardedLoader(_zipf(batch_size=128, num_batches=3), mesh)
    assert len(eng.fit_sgd(loader)) == 3
    assert loader.cursor == Cursor(1, 0)
    with pytest.raises(ValueError, match="unbounded"):
        eng.fit_sgd(ShardedLoader(_zipf(batch_size=128), mesh))


def test_load_state_dict_rejects_host_count_mismatch():
    mesh = make_host_mesh(1, 1)
    saved = ShardedLoader(_zipf(num_batches=8), mesh, host_index=1,
                          num_hosts=2).state_dict()
    single = ShardedLoader(_zipf(num_batches=8), mesh)
    with pytest.raises(ValueError, match="num_hosts"):
        single.load_state_dict(saved)
    with pytest.warns(RuntimeWarning, match="source"):
        single.load_state_dict({"cursor": {"epoch": 0, "step": 1},
                                "source": "file_sparse", "num_hosts": 1})
    assert single.cursor == Cursor(0, 1)
    with pytest.warns(RuntimeWarning, match="batch_size"):
        single.load_state_dict({"cursor": {"epoch": 0, "step": 2},
                                "source": "zipf_sparse", "batch_size": 32,
                                "num_hosts": 1})
    assert single.cursor == Cursor(0, 2)


def test_epoch_normalizes_overshot_cursor():
    """A cursor at/past the epoch boundary rolls into the next epoch instead
    of producing a negative limit that silently yields nothing."""
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(num_batches=4), mesh, prefetch=0)
    loader.seek(Cursor(0, 9))
    got = list(loader.epoch())
    assert len(got) == 4 and loader.cursor == Cursor(2, 0)


def test_fit_rewinds_mid_epoch_cursor_to_full_pass():
    """fit() iterations must each average the WHOLE corpus: a loader left
    mid-epoch by earlier SGD is rewound to its epoch start (regression:
    the first iteration averaged only the epoch remainder)."""
    mesh = make_host_mesh(1, 1)
    loader = ShardedLoader(_zipf(batch_size=128, num_batches=4), mesh)
    a = DPMREngine(_cfg(iterations=1), mesh)
    a.fit_sgd(loader, steps=2)              # cursor now (0, 2)
    # snapshot by COPY: the engine's updating steps donate their input
    # state, so a bare reference dies with the next fit/train_step
    pre_sgd_state = jax.tree.map(jnp.copy, a.state)
    a.fit(loader)
    b = DPMREngine(_cfg(iterations=1), mesh, state=pre_sgd_state)
    b.fit(ShardedLoader(_zipf(batch_size=128, num_batches=4), mesh))
    np.testing.assert_array_equal(np.asarray(a.state.cold),
                                  np.asarray(b.state.cold))


def test_engine_fit_loader_matches_batch_iter_fn():
    """The loader path and the legacy batch_iter_fn path are numerically
    identical (same batches, same update order)."""
    mesh = make_host_mesh(1, 1)
    src = _zipf(batch_size=128, num_batches=3)
    a = DPMREngine(_cfg(), mesh)
    a.fit(lambda: src.iter_batches())
    b = DPMREngine(_cfg(), mesh)
    b.fit(ShardedLoader(_zipf(batch_size=128, num_batches=3), mesh))
    np.testing.assert_array_equal(np.asarray(a.state.cold),
                                  np.asarray(b.state.cold))
