"""Per-arch smoke tests: reduced same-family configs, one forward/train step
on CPU, asserting output shapes + finiteness; plus prefill->decode vs
teacher-forced forward consistency (the serve path computes the same math).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import ARCH_IDS
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.models.common import embed_init_scale
from repro.sharding import init_from_defs
from repro.train import trainer


def _params(spec, cfg, key=0):
    return init_from_defs(spec.defs(cfg), jax.random.PRNGKey(key),
                          scale_fn=embed_init_scale)


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.smoke_config(arch)
    spec = registry.get_spec(arch)
    params = _params(spec, cfg)
    batch = _batch(cfg)
    logits, aux = spec.forward(params, batch, cfg, None)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), arch
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = registry.smoke_config(arch)
    spec = registry.get_spec(arch)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=10)
    pc = ParallelConfig(microbatches=1)
    with compat.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
        step = jax.jit(trainer.make_train_step(spec, cfg, tc, pc, mesh))
        b = _batch(cfg, b=4, s=16)
        state, m = step(state, b)
        state, m2 = step(state, _batch(cfg, b=4, s=16, seed=1))
    assert np.isfinite(float(m["loss"])) and np.isfinite(float(m2["loss"]))
    for leaf in jax.tree.leaves(state["params"]):
        assert jnp.all(jnp.isfinite(leaf))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """prefill(S) + decode(2 tokens) must reproduce the teacher-forced
    logits at the same positions (serve path == train math)."""
    cfg = registry.smoke_config(arch)
    spec = registry.get_spec(arch)
    params = _params(spec, cfg)
    b, s = 2, 12
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, size=(b, s + 2)).astype(np.int32)
    full_batch = {"tokens": jnp.asarray(toks)}
    pre_batch = {"tokens": jnp.asarray(toks[:, :s])}
    if cfg.family == "encdec":
        frames = rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
        # teacher-forced forward must see the SAME encoder input
        full_batch["frames"] = jnp.asarray(frames)
        pre_batch["frames"] = jnp.asarray(frames)

    parallel = ParallelConfig(seq_shard=False, remat="none")
    logits_full, _ = spec.forward(params, full_batch, cfg, parallel)
    logits_p, cache = spec.prefill(params, pre_batch, cfg, parallel)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(logits_full[:, s - 1]),
        rtol=2e-2, atol=2e-2)

    logits_d1, cache = spec.decode_step(
        params, cache, jnp.asarray(toks[:, s:s + 1]), cfg)
    np.testing.assert_allclose(
        np.asarray(logits_d1[:, 0]), np.asarray(logits_full[:, s]),
        rtol=2e-2, atol=2e-2)
    logits_d2, _ = spec.decode_step(
        params, cache, jnp.asarray(toks[:, s + 1:s + 2]), cfg)
    np.testing.assert_allclose(
        np.asarray(logits_d2[:, 0]), np.asarray(logits_full[:, s + 1]),
        rtol=2e-2, atol=2e-2)


def test_swa_matches_full_attention_within_window():
    """Mixtral's SWA must equal full attention when S <= window."""
    from repro.models import layers

    rng = np.random.default_rng(0)
    b, s, h, kh, d = 2, 16, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    full = layers.blocked_causal_attention(q, k, v, q_block=8, kv_block=8)
    swa = layers.blocked_causal_attention(q, k, v, window=s, q_block=8,
                                          kv_block=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa),
                               rtol=1e-5, atol=1e-5)


def test_swa_restricts_receptive_field():
    """Changing a token outside the window must not change the output."""
    from repro.models import layers

    rng = np.random.default_rng(1)
    b, s, h, d, w = 1, 32, 2, 8, 4
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out1 = layers.blocked_causal_attention(q, k, v, window=w, q_block=8,
                                           kv_block=8)
    k2 = k.at[:, 0].add(10.0)   # outside the window of positions >= w
    v2 = v.at[:, 0].add(10.0)
    out2 = layers.blocked_causal_attention(q, k2, v2, window=w, q_block=8,
                                           kv_block=8)
    np.testing.assert_allclose(np.asarray(out1[:, w:]),
                               np.asarray(out2[:, w:]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]))


def test_blocked_attention_matches_reference():
    from repro.kernels import ref
    from repro.models import layers

    rng = np.random.default_rng(2)
    for (b, s, h, kh, d) in [(2, 64, 4, 2, 16), (1, 48, 3, 1, 8)]:
        q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
        blocked = layers.blocked_causal_attention(q, k, v, q_block=16,
                                                  kv_block=16)
        oracle = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(oracle),
                                   rtol=2e-5, atol=2e-5)


def test_masked_scan_attention_matches_triangular():
    from repro.models.layers import (_masked_scan_attention,
                                     _triangular_attention)

    rng = np.random.default_rng(4)
    b, s, h, d = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    a = _triangular_attention(q, k, v, 16, 16, d ** -0.5)
    m = _masked_scan_attention(q, k, v, 16, 16, d ** -0.5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(m), rtol=2e-5,
                               atol=2e-5)
