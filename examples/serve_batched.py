"""Batched serving example: prefill a prompt batch, then greedy-decode.

Exercises the production serve path (prefill -> KV/state cache -> decode
steps) for a dense, an SSM, and an MoE architecture. Prompt batches come
from the `repro.data` plane (`lm_markov` source behind a ShardedLoader), so
the serve path consumes the same loader abstraction the trainers do.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --no-smoke \
        --archs yi-6b --decode-steps 4     # full config (slow on CPU)
"""
import argparse
import time

import jax
import numpy as np

from repro import compat
from repro.configs.base import ParallelConfig, TrainConfig
from repro.data import ShardedLoader, get_source
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.train import serve, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced same-family configs (--no-smoke = full)")
    ap.add_argument("--archs", nargs="+",
                    default=["yi-6b", "xlstm-125m", "phi3.5-moe-42b-a6.6b"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=12)
    args = ap.parse_args()

    mesh = make_host_mesh(1, 1)

    for arch in args.archs:
        cfg = registry.smoke_config(arch) if args.smoke else \
            registry.get_spec(arch).cfg
        spec = registry.get_spec(arch)
        # prompts through the data plane: one loader batch per arch
        prompts = ShardedLoader(
            get_source("lm_markov", vocab_size=cfg.vocab_size,
                       seq_len=args.prompt_len, batch_size=args.batch,
                       encdec_d_model=(cfg.d_model if cfg.family == "encdec"
                                       else 0)),
            mesh, placement="device", prefetch=0)
        with compat.set_mesh(mesh):
            state = trainer.init_state(spec, cfg,
                                       TrainConfig(optimizer="sgd"),
                                       ParallelConfig(), jax.random.PRNGKey(1))
            lm_batch = next(iter(prompts.batches(1)))
            batch = {"tokens": lm_batch["tokens"]}
            if cfg.family == "encdec":
                batch["frames"] = lm_batch["frames"]
            t0 = time.time()
            toks = serve.greedy_decode(spec, cfg, state["params"], batch,
                                       args.decode_steps,
                                       ParallelConfig(seq_shard=False))
            dt = time.time() - t0
        print(f"{arch:24s} decoded {toks.shape[0]}x{toks.shape[1]} tokens "
              f"in {dt:5.2f}s -> {np.asarray(toks[0, :8])}")
    print("OK")


if __name__ == "__main__":
    main()
