"""Batched serving example: prefill a prompt batch, then greedy-decode.

Exercises the production serve path (prefill -> KV/state cache -> decode
steps) for a dense, an SSM, and an MoE architecture.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.train import serve, trainer

mesh = make_host_mesh(1, 1)
rng = np.random.default_rng(0)

for arch in ("yi-6b", "xlstm-125m", "phi3.5-moe-42b-a6.6b"):
    cfg = registry.smoke_config(arch)
    spec = registry.get_spec(arch)
    with jax.set_mesh(mesh):
        state = trainer.init_state(spec, cfg, TrainConfig(optimizer="sgd"),
                                   ParallelConfig(), jax.random.PRNGKey(1))
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(4, 32)), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(4, 32, cfg.d_model)), jnp.float32)
        t0 = time.time()
        toks = serve.greedy_decode(spec, cfg, state["params"], batch, 12,
                                   ParallelConfig(seq_shard=False))
        dt = time.time() - t0
    print(f"{arch:24s} decoded {toks.shape[0]}x{toks.shape[1]} tokens "
          f"in {dt:5.2f}s -> {np.asarray(toks[0, :8])}")
print("OK")
