"""LM pretraining example on the DPMR-dense (FSDP) sharded trainer.

Any of the 10 assigned architectures is selectable; reduced same-family
configs keep it CPU-runnable. Shows: sharded params/optimizer, microbatch
grad accumulation, checkpoint/resume, preemption-safe saves.

    PYTHONPATH=src python examples/train_lm.py --arch yi-6b --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x22b  # MoE
"""
import argparse
import logging

from repro.launch.train import build_parser, train_loop


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=60)
    args, _ = ap.parse_known_args()

    targs = build_parser().parse_args([
        "--arch", args.arch, "--smoke", "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--microbatches", "2",
        "--ckpt", f"/tmp/repro_ck_{args.arch.replace('/', '_')}",
        "--save-every", "20", "--log-every", "10",
    ])
    out = train_loop(targs)
    print(f"{args.arch}: loss {out['losses'][0]:.3f} -> "
          f"{out['losses'][-1]:.3f} over {out['last_step']} steps")
    assert out["losses"][-1] < out["losses"][0]


if __name__ == "__main__":
    main()
