"""End-to-end driver: train a ~100M-PARAMETER model for a few hundred steps.

The paper's model class is sparse logistic regression over huge feature
spaces — its "~100M model" is a 100M-feature table (the paper itself runs
50B). This driver runs minibatch DPMR-SGD for a few hundred steps over a
synthetic Zipf corpus of that scale, with hot-feature replication, and
reports convergence + test metrics.

    PYTHONPATH=src python examples/train_dpmr_100m.py            # 2^24 feats
    PYTHONPATH=src python examples/train_dpmr_100m.py --log2-features 27
"""
import argparse
import time

import jax

from repro.configs.base import DPMRConfig
from repro.core import sparse_lr
from repro.data import sparse_corpus
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log2-features", type=int, default=24,
                    help="27 => ~134M features/params")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4096)
    args = ap.parse_args()

    f = 1 << args.log2_features
    corpus = sparse_corpus.CorpusSpec(num_features=f,
                                      features_per_sample=64,
                                      signal_features=4096)
    cfg = DPMRConfig(num_features=f, max_features_per_sample=64,
                     learning_rate=2.0, max_hot=512, optimizer="adagrad")
    mesh = make_host_mesh(1, 1)

    hot = sparse_lr.hot_ids_from_corpus(
        cfg, sparse_corpus.batches(corpus, args.batch, 4), mesh)

    t0 = time.time()
    with jax.set_mesh(mesh):
        out = sparse_lr.dpmr_train_sgd(
            cfg, mesh,
            sparse_corpus.batches(corpus, args.batch, args.steps),
            args.batch, hot_ids=hot)
        test = list(sparse_corpus.batches(corpus, args.batch, 1003,
                                          start=1000))
        metrics = sparse_lr.evaluate(out["state"], out["fns"], test, mesh)
    dt = time.time() - t0

    h = out["history"]
    print(f"features={f:.2e} steps={args.steps} batch={args.batch}")
    print(f"loss: {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"({args.steps * args.batch / dt:.0f} samples/s)")
    print("test:", {k: round(v, 3) for k, v in metrics.items()
                    if "avg" in k})


if __name__ == "__main__":
    main()
