"""End-to-end driver: train a ~100M-PARAMETER model for a few hundred steps.

The paper's model class is sparse logistic regression over huge feature
spaces — its "~100M model" is a 100M-feature table (the paper itself runs
50B). This driver runs minibatch DPMR-SGD through `DPMREngine` for a few
hundred steps over a synthetic Zipf corpus of that scale, with hot-feature
replication, and reports convergence + test metrics. `--distribution`
selects any registered strategy; `--ckpt` exercises the engine's sparse
checkpoint story.

    PYTHONPATH=src python examples/train_dpmr_100m.py            # 2^24 feats
    PYTHONPATH=src python examples/train_dpmr_100m.py --log2-features 27 \
        --distribution psum_scatter --ckpt /tmp/dpmr100m
"""
import argparse
import time

from repro.api import (DPMREngine, ShardedLoader, get_source,
                       hot_ids_from_corpus, list_strategies)
from repro.configs.base import DPMRConfig
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log2-features", type=int, default=24,
                    help="27 => ~134M features/params")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--distribution", default="a2a",
                    choices=list_strategies())
    ap.add_argument("--prefetch", type=int, default=2,
                    help="loader prefetch depth (0 = synchronous input)")
    ap.add_argument("--ckpt", default="",
                    help="save the trained sparse state here")
    args = ap.parse_args()

    f = 1 << args.log2_features
    corpus = dict(num_features=f, features_per_sample=64,
                  signal_features=4096)
    cfg = DPMRConfig(num_features=f, max_features_per_sample=64,
                     learning_rate=2.0, max_hot=512, optimizer="adagrad",
                     distribution=args.distribution)
    mesh = make_host_mesh(1, 1)

    # data plane: an unbounded synthetic stream behind a prefetching loader
    # (batch synthesis + device placement overlap the training step)
    train = ShardedLoader(
        get_source("zipf_sparse", batch_size=args.batch, **corpus),
        mesh, prefetch=args.prefetch)
    test = ShardedLoader(
        get_source("zipf_sparse", batch_size=args.batch, num_batches=3,
                   start=1000, **corpus), mesh)

    hot = hot_ids_from_corpus(cfg, train.source.iter_batches(limit=4), mesh)
    engine = DPMREngine(cfg, mesh, hot_ids=hot)

    t0 = time.time()
    history = engine.fit_sgd(train, steps=args.steps)
    metrics = engine.evaluate(test)
    dt = time.time() - t0

    print(f"features={f:.2e} steps={args.steps} batch={args.batch} "
          f"strategy={args.distribution}")
    print(f"loss: {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f} "
          f"({args.steps * args.batch / dt:.0f} samples/s)")
    print("test:", {k: round(v, 3) for k, v in metrics.items()
                    if "avg" in k})
    if args.ckpt:
        step = engine.save(args.ckpt)
        print(f"saved sparse checkpoint at step {step} -> {args.ckpt}")


if __name__ == "__main__":
    main()
