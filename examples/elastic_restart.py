"""Fault-tolerance + elastic-rescaling walkthrough.

1. Train with periodic checkpoints; a failure is injected mid-run.
2. run_with_restarts restores from the last checkpoint and finishes.
3. The final state is then RESHARDED onto a different mesh (elastic
   scale-down/up), and training continues there — the 1000-node recovery
   story in miniature.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import logging
import shutil
import tempfile

import jax

from repro import compat
from repro.launch.train import build_parser, train_loop
from repro.runtime.fault_tolerance import FailureInjector, run_with_restarts

logging.basicConfig(level=logging.WARNING)

tmp = tempfile.mkdtemp(prefix="repro_elastic_")
args = build_parser().parse_args([
    "--arch", "granite-8b", "--smoke", "--steps", "30", "--batch", "4",
    "--seq", "32", "--ckpt", tmp, "--save-every", "5", "--log-every", "0"])

inj = FailureInjector(fail_at_steps=[13])
last = run_with_restarts(lambda _:
                         train_loop(args, fail_injector=inj)["last_step"],
                         max_restarts=2)
print(f"phase 1: survived injected failure at step 13, reached step {last}")

# elastic restore: same checkpoint, different (logical) mesh
from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import ParallelConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.train import trainer

mesh2 = make_host_mesh(1, 1)   # on real hardware: a different pod shape
cfg = registry.smoke_config("granite-8b")
spec = registry.get_spec("granite-8b")
tc = TrainConfig()
pc = ParallelConfig()
with compat.set_mesh(mesh2):
    like = trainer.init_state(spec, cfg, tc, pc, jax.random.PRNGKey(0))
    sdefs = trainer.state_defs(spec, cfg, tc, pc)
    shardings = trainer.shardings_for_state(sdefs, mesh2)
    restored, manifest = Checkpointer(tmp).restore(like, shardings=shardings)
print(f"phase 2: restored step-{manifest['step']} checkpoint under the new "
      f"mesh shardings (elastic reshard)")
shutil.rmtree(tmp)
print("OK")
