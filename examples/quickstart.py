"""Quickstart: train + classify distributed sparse logistic regression with
Distributed Parameter Map-Reduce (the paper's Algorithm 8 + 9) in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import DPMRConfig
from repro.core import sparse_lr
from repro.data import sparse_corpus
from repro.launch.mesh import make_host_mesh

# a Zipf-distributed sparse corpus (the paper's CTR-log regime, scaled down)
corpus = sparse_corpus.CorpusSpec(num_features=1 << 14,
                                  features_per_sample=32,
                                  signal_features=512)
cfg = DPMRConfig(num_features=1 << 14, max_features_per_sample=32,
                 iterations=6, learning_rate=2.0, max_hot=64,
                 optimizer="adagrad")

mesh = make_host_mesh(1, 1)   # every device = one DPMR node (samples+params)
train_batches = lambda: sparse_corpus.batches(corpus, 512, 8)
test_batches = list(sparse_corpus.batches(corpus, 512, 54, start=50))

# initParameters-time frequency stats -> replicated Zipf head (paper sec. 4)
hot = sparse_lr.hot_ids_from_corpus(cfg, train_batches(), mesh)

with jax.set_mesh(mesh):
    out = sparse_lr.dpmr_train(cfg, mesh, train_batches, 512, hot_ids=hot)
    metrics = sparse_lr.evaluate(out["state"], out["fns"], test_batches,
                                 mesh)

print("loss per iteration:",
      [round(h["loss"], 4) for h in out["history"]])
print("test metrics:", {k: round(v, 3) for k, v in metrics.items()})
assert metrics["f_avg"] > 0.5
print("OK - DPMR trained and classified on a", mesh.shape, "mesh")
