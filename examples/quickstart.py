"""Quickstart: train + classify distributed sparse logistic regression with
Distributed Parameter Map-Reduce (the paper's Algorithm 8 + 9) through the
typed `DPMREngine` façade and the `repro.data` plane, in ~25 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import (DPMREngine, ShardedLoader, get_source,
                       hot_ids_from_corpus, list_sources, list_strategies)
from repro.configs.base import DPMRConfig
from repro.launch.mesh import make_host_mesh

# a Zipf-distributed sparse corpus (the paper's CTR-log regime, scaled down)
corpus = dict(num_features=1 << 14, features_per_sample=32,
              signal_features=512)
cfg = DPMRConfig(num_features=1 << 14, max_features_per_sample=32,
                 iterations=6, learning_rate=2.0, max_hot=64,
                 optimizer="adagrad")     # distribution="a2a" is the default;
#                                          any name in list_strategies() works

mesh = make_host_mesh(1, 1)   # every device = one DPMR node (samples+params)
# data plane: named sources behind prefetching, cursor-resumable loaders
train = ShardedLoader(get_source("zipf_sparse", batch_size=512,
                                 num_batches=8, **corpus), mesh)
test = ShardedLoader(get_source("zipf_sparse", batch_size=512, num_batches=4,
                                start=50, **corpus), mesh)

# initParameters-time frequency stats -> replicated Zipf head (paper sec. 4)
hot = hot_ids_from_corpus(cfg, train.source.iter_batches(), mesh)

engine = DPMREngine(cfg, mesh, hot_ids=hot)
history = engine.fit(train)         # one loader epoch per paper iteration
metrics = engine.evaluate(test)

print("strategies available:", list_strategies())
print("data sources available:", list_sources())
print("loss per iteration:", [round(h["loss"], 4) for h in history])
print("train cursor after fit:", train.cursor)
print("test metrics:", {k: round(v, 3) for k, v in metrics.items()})
assert metrics["f_avg"] > 0.5
print("OK - DPMR trained and classified on a", mesh.shape, "mesh")
